//! Bit-packed binary vector. COSIME stores and searches *binary* words
//! (paper §3.1 assumes bits ∈ {0,1}); the digital reference engine and the
//! coordinator hot path operate on u64 lanes so a 1024-bit word is 16 words of
//! AND + POPCNT instead of 1024 byte ops.

/// A fixed-length binary vector packed into u64 lanes (LSB-first within lane).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    len: usize,
    lanes: Vec<u64>,
}

impl std::fmt::Debug for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitVec(len={}, ones={})", self.len, self.count_ones())
    }
}

impl BitVec {
    /// All-zeros vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { len, lanes: vec![0; len.div_ceil(64)] }
    }

    /// Build from a slice of bits (anything nonzero is a 1).
    pub fn from_bits(bits: &[u8]) -> Self {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b != 0 {
                v.set(i, true);
            }
        }
        v
    }

    /// Build from an iterator of booleans.
    pub fn from_bools<I: IntoIterator<Item = bool>>(iter: I) -> Self {
        let bits: Vec<u8> = iter.into_iter().map(u8::from).collect();
        Self::from_bits(&bits)
    }

    /// Random vector with each bit ~ Bernoulli(density).
    pub fn random(len: usize, density: f64, rng: &mut super::Rng) -> Self {
        // Fast path for the ubiquitous unbiased case: one PRNG draw fills a
        // whole lane (§Perf — load generation dominated several benches).
        if (density - 0.5).abs() < 1e-12 {
            let mut v = BitVec::zeros(len);
            for lane in v.lanes.iter_mut() {
                *lane = rng.next_u64();
            }
            // Clear the bits beyond len in the trailing lane.
            let tail = len % 64;
            if tail != 0 {
                *v.lanes.last_mut().unwrap() &= (1u64 << tail) - 1;
            }
            return v;
        }
        Self::from_bools((0..len).map(|_| rng.bool(density)))
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the vector has zero bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Raw u64 lanes (LSB-first). Trailing bits beyond `len` are zero.
    pub fn lanes(&self) -> &[u64] {
        &self.lanes
    }

    /// Rewrite this vector in place to `len` bits copied from `lanes` (as
    /// produced by [`BitVec::lanes`]: LSB-first, trailing bits zero),
    /// reusing the existing allocation — the staging step of the
    /// allocation-free search kernel.
    pub fn assign_lanes(&mut self, len: usize, lanes: &[u64]) {
        assert_eq!(lanes.len(), len.div_ceil(64), "lane count mismatch for {len} bits");
        // Every score/popcount routine relies on the trailing bits being
        // zero; a caller handing in dirty lanes would get silently wrong
        // winners, so catch it in debug builds.
        debug_assert!(
            len % 64 == 0 || lanes[lanes.len() - 1] >> (len % 64) == 0,
            "bits beyond len={len} must be zero"
        );
        self.len = len;
        self.lanes.clear();
        self.lanes.extend_from_slice(lanes);
    }

    /// Get bit `i`.
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "bit index {i} out of range {}", self.len);
        (self.lanes[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i`.
    pub fn set(&mut self, i: usize, val: bool) {
        assert!(i < self.len, "bit index {i} out of range {}", self.len);
        let (lane, off) = (i / 64, i % 64);
        if val {
            self.lanes[lane] |= 1 << off;
        } else {
            self.lanes[lane] &= !(1 << off);
        }
    }

    /// Flip bit `i`, returning the new value.
    pub fn flip(&mut self, i: usize) -> bool {
        let v = !self.get(i);
        self.set(i, v);
        v
    }

    /// Popcount: number of 1s (`‖b‖²` for a binary vector — paper Eq. 2's Y).
    pub fn count_ones(&self) -> u32 {
        self.lanes.iter().map(|l| l.count_ones()).sum()
    }

    /// Binary dot product with `other` (`a·b` — paper Eq. 2's X), via the
    /// crate's one popcount inner loop ([`crate::am::kernel::simd`]).
    pub fn dot(&self, other: &BitVec) -> u32 {
        assert_eq!(self.len, other.len, "dot of mismatched lengths");
        crate::am::kernel::simd::and_popcount(&self.lanes, &other.lanes)
    }

    /// Hamming distance to `other`.
    pub fn hamming(&self, other: &BitVec) -> u32 {
        assert_eq!(self.len, other.len, "hamming of mismatched lengths");
        crate::am::kernel::simd::xor_popcount(&self.lanes, &other.lanes)
    }

    /// Squared cosine similarity to `other`: `(a·b)² / (‖a‖²‖b‖²)` (paper Eq. 2).
    /// Returns 0 for degenerate (all-zero) operands.
    pub fn cos2(&self, other: &BitVec) -> f64 {
        let x = self.dot(other) as f64;
        let na = self.count_ones() as f64;
        let nb = other.count_ones() as f64;
        if na == 0.0 || nb == 0.0 {
            return 0.0;
        }
        x * x / (na * nb)
    }

    /// Unpack to a byte-per-bit vector (for marshalling into XLA literals).
    pub fn to_bytes(&self) -> Vec<u8> {
        (0..self.len).map(|i| u8::from(self.get(i))).collect()
    }

    /// Unpack to f32 per bit (for the exact-cosine XLA path).
    pub fn to_f32(&self) -> Vec<f32> {
        (0..self.len).map(|i| f32::from(u8::from(self.get(i)))).collect()
    }

    /// Iterate over bits.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let bits = [1u8, 0, 1, 1, 0, 0, 1, 0, 1];
        let v = BitVec::from_bits(&bits);
        assert_eq!(v.len(), 9);
        assert_eq!(v.to_bytes(), bits);
        assert_eq!(v.count_ones(), 5);
    }

    #[test]
    fn dot_and_hamming() {
        let a = BitVec::from_bits(&[1, 1, 0, 0, 1]);
        let b = BitVec::from_bits(&[1, 0, 0, 1, 1]);
        assert_eq!(a.dot(&b), 2);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.dot(&a), a.count_ones());
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn cos2_matches_definition() {
        let a = BitVec::from_bits(&[1, 1, 1, 0]);
        let b = BitVec::from_bits(&[1, 1, 0, 0]);
        // dot=2, |a|²=3, |b|²=2 → 4/6
        assert!((a.cos2(&b) - 4.0 / 6.0).abs() < 1e-12);
        assert!((a.cos2(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cos2_degenerate_zero_vector() {
        let a = BitVec::zeros(8);
        let b = BitVec::from_bits(&[1, 0, 0, 0, 0, 0, 0, 0]);
        assert_eq!(a.cos2(&b), 0.0);
        assert_eq!(b.cos2(&a), 0.0);
        assert_eq!(a.cos2(&a), 0.0);
    }

    #[test]
    fn set_get_flip_across_lane_boundary() {
        let mut v = BitVec::zeros(130);
        v.set(0, true);
        v.set(63, true);
        v.set(64, true);
        v.set(129, true);
        assert_eq!(v.count_ones(), 4);
        assert!(v.get(63) && v.get(64));
        assert!(!v.flip(63));
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn trailing_lane_bits_stay_zero() {
        let v = BitVec::from_bits(&[1; 70]);
        // 70 ones even though two u64 lanes could hold 128.
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.lanes()[1] >> 6, 0);
    }

    #[test]
    #[should_panic(expected = "mismatched")]
    fn dot_length_mismatch_panics() {
        let a = BitVec::zeros(8);
        let b = BitVec::zeros(9);
        let _ = a.dot(&b);
    }

    #[test]
    fn assign_lanes_reuses_storage_and_roundtrips() {
        let mut r = crate::util::rng(9);
        let src = BitVec::random(130, 0.5, &mut r);
        let mut dst = BitVec::zeros(0);
        dst.assign_lanes(src.len(), src.lanes());
        assert_eq!(dst, src);
        // Shrinking reassignment must also roundtrip.
        let small = BitVec::from_bits(&[1, 0, 1]);
        dst.assign_lanes(3, small.lanes());
        assert_eq!(dst, small);
    }

    #[test]
    #[should_panic(expected = "lane count mismatch")]
    fn assign_lanes_rejects_bad_lane_count() {
        let mut v = BitVec::zeros(0);
        v.assign_lanes(70, &[0u64]);
    }

    #[test]
    fn random_density_is_plausible() {
        let mut r = crate::util::rng(7);
        let v = BitVec::random(10_000, 0.3, &mut r);
        let d = v.count_ones() as f64 / 10_000.0;
        assert!((d - 0.3).abs() < 0.03, "density {d}");
    }
}
