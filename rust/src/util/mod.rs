//! Shared utilities, all implemented in-crate because the build environment
//! is fully offline (see `.cargo/config.toml`): bit-packed binary vectors,
//! a seeded PRNG, JSON and TOML-subset codecs, a micro-benchmark harness,
//! a property-testing runner, a parallel map, and a tiny CLI parser.

mod bitvec;
/// Micro-benchmark runner behind `cosime bench`.
pub mod bench;
/// Dependency-free CLI argument parsing.
pub mod cli;
/// Deterministic TCP fault injection for failover tests.
pub mod fault;
/// Minimal JSON value, parser, and pretty-printer.
pub mod json;
/// Scoped-thread fork/join helpers.
pub mod par;
/// Tiny property-testing harness (seeded shrinking).
pub mod prop;
mod rng;
/// Seeded deterministic-interleaving harness for concurrency tests.
pub mod sched;
mod stats;
/// Lock classes, runtime lockdep, and poison-recovering lock helpers.
pub mod sync;
/// Minimal TOML subset parser for `cosime.toml`.
pub mod toml_lite;

pub use bitvec::BitVec;
pub use rng::Rng;
pub use stats::{mean, percentile, stddev, Histogram, RunningStats};

/// Crate-wide deterministic RNG constructor. Every stochastic component takes
/// an explicit seed so paper figures regenerate bit-identically.
pub fn rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

/// 64-bit FNV-1a over a byte stream — the one hash family shared by the
/// snapshot fingerprint ([`crate::config::CosimeConfig::physical_fingerprint`])
/// and shard placement ([`crate::server::shard::fnv1a_word`]), so the two
/// cannot drift apart.
pub fn fnv1a_bytes(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Derive a child seed from a parent seed and a stream index (splitmix64 hop).
pub fn child_seed(seed: u64, stream: u64) -> u64 {
    let mut z = seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    #[test]
    fn child_seeds_distinct() {
        let s = 42;
        let a = super::child_seed(s, 0);
        let b = super::child_seed(s, 1);
        assert_ne!(a, b);
        assert_eq!(a, super::child_seed(s, 0));
    }

    /// Published FNV-1a 64-bit test vectors: the offset basis for the empty
    /// stream and the reference hash of "a".
    #[test]
    fn fnv1a_matches_reference_vectors() {
        assert_eq!(super::fnv1a_bytes([]), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a_bytes(*b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a_bytes(b"foobar".iter().copied()), 0x8594_4171_f739_67e8);
    }
}
