//! Deterministic TCP fault injection for failover tests.
//!
//! [`FaultProxy`] sits between a wire client and a `cosimed` server as a
//! byte relay, and misbehaves *on schedule*: each accepted connection is
//! assigned the next [`FaultAction`] from a fixed list, so "the third
//! connection dies after 512 bytes" is a reproducible fact of the test,
//! not a race. On top of the per-connection schedule the proxy has one
//! global switch — [`FaultProxy::partition`] — that severs every live
//! relay and refuses new ones until [`FaultProxy::heal`], which is how
//! kill-one-shard and partition-and-rejoin scenarios are scripted.
//!
//! Determinism model: actions are consumed in **accept order**, and the
//! schedule itself can be derived from a seed ([`seeded_schedule`]), so a
//! failing fault run is re-playable from its seed alone. Timing-dependent
//! interleaving is kept out of the *assertions* — tests assert on typed
//! results (partial flags, typed errors, bit-exact survivors), never on
//! how fast a byte moved.
//!
//! The proxy is test infrastructure first, but lives in `util` (not under
//! `#[cfg(test)]`) so integration tests, the fuzz rail and future chaos
//! tooling share one implementation.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::rng;
use super::sync::{TrackedMutex, FAULT_LIVE};

/// What the proxy does to one relayed connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Forward bytes untouched.
    None,
    /// Forward this many bytes (both directions share the budget), then
    /// sever both sides mid-stream — the "shard died mid-response" and
    /// "snapshot stream cut" fault.
    CloseAfterBytes(u64),
    /// Sleep this long before forwarding each read chunk — the slow-shard
    /// fault (results must stay correct, just late).
    DelayChunks(Duration),
    /// Accept, then immediately close without forwarding anything — the
    /// "port open, service gone" fault.
    RefuseBytes,
}

/// Derive a reproducible mixed fault schedule from a seed: same seed, same
/// `len` → the same action sequence, on any machine.
pub fn seeded_schedule(seed: u64, len: usize) -> Vec<FaultAction> {
    let mut r = rng(seed);
    (0..len)
        .map(|_| match r.below(5) {
            0 | 1 => FaultAction::None,
            2 => FaultAction::CloseAfterBytes(1 + r.below(4096) as u64),
            3 => FaultAction::DelayChunks(Duration::from_millis(1 + r.below(4) as u64)),
            _ => FaultAction::RefuseBytes,
        })
        .collect()
}

struct ProxyShared {
    upstream: SocketAddr,
    /// Per-connection actions, consumed in accept order; connections past
    /// the end of the schedule relay untouched.
    schedule: Vec<FaultAction>,
    accepted: AtomicU64,
    /// Global partition switch: sever live relays, refuse new ones.
    partitioned: AtomicBool,
    running: AtomicBool,
    /// Both sockets of every live relay, so [`FaultProxy::partition`] can
    /// sever in-flight connections, not just refuse new ones. This is the
    /// `fault.live` lock class in [`super::sync::lock_order`].
    live: TrackedMutex<Vec<TcpStream>>,
}

impl ProxyShared {
    fn sever_live(&self) {
        let mut live = self.live.lock();
        for s in live.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

/// A deterministic fault-injecting TCP relay (module docs). Dropping the
/// proxy without [`FaultProxy::shutdown`] leaks its accept thread for the
/// remainder of the process — fine in tests, call `shutdown` anyway.
pub struct FaultProxy {
    addr: SocketAddr,
    shared: Arc<ProxyShared>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Start a relay on an ephemeral local port in front of `upstream`.
    /// Connection `i` (accept order) gets `schedule[i]`; connections past
    /// the schedule relay untouched.
    pub fn start(
        upstream: SocketAddr,
        schedule: Vec<FaultAction>,
    ) -> std::io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(ProxyShared {
            upstream,
            schedule,
            accepted: AtomicU64::new(0),
            partitioned: AtomicBool::new(false),
            running: AtomicBool::new(true),
            live: TrackedMutex::new(&FAULT_LIVE, Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept_thread = thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(FaultProxy { addr, shared, accept_thread: Some(accept_thread) })
    }

    /// Address clients should dial instead of the upstream's.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections accepted so far (monotone; includes refused ones).
    pub fn accepted(&self) -> u64 {
        self.shared.accepted.load(Ordering::Acquire)
    }

    /// Sever every live relay and refuse new connections until
    /// [`FaultProxy::heal`] — the network partition switch.
    pub fn partition(&self) {
        self.shared.partitioned.store(true, Ordering::Release);
        self.shared.sever_live();
    }

    /// Lift a [`FaultProxy::partition`]: new connections relay again
    /// (consuming the schedule where it left off).
    pub fn heal(&self) {
        self.shared.partitioned.store(false, Ordering::Release);
    }

    /// Stop accepting, sever everything, and join the accept thread.
    pub fn shutdown(mut self) {
        self.shared.running.store(false, Ordering::Release);
        self.shared.partitioned.store(true, Ordering::Release);
        self.shared.sever_live();
        // Unblock the accept loop with a throwaway dial.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<ProxyShared>) {
    while shared.running.load(Ordering::Acquire) {
        let Ok((client, _)) = listener.accept() else { break };
        if !shared.running.load(Ordering::Acquire) {
            break;
        }
        let idx = shared.accepted.fetch_add(1, Ordering::AcqRel) as usize;
        if shared.partitioned.load(Ordering::Acquire) {
            let _ = client.shutdown(Shutdown::Both);
            continue;
        }
        let action = shared.schedule.get(idx).copied().unwrap_or(FaultAction::None);
        if action == FaultAction::RefuseBytes {
            let _ = client.shutdown(Shutdown::Both);
            continue;
        }
        let Ok(server) = TcpStream::connect(shared.upstream) else {
            let _ = client.shutdown(Shutdown::Both);
            continue;
        };
        let _ = client.set_nodelay(true);
        let _ = server.set_nodelay(true);
        {
            let mut live = shared.live.lock();
            if let (Ok(c), Ok(s)) = (client.try_clone(), server.try_clone()) {
                live.push(c);
                live.push(s);
            }
        }
        // Both directions draw on one byte budget so "dies after N bytes"
        // covers request *or* response truncation, wherever N lands in the
        // (sequential, request-response) exchange.
        let budget: Arc<AtomicI64> = Arc::new(AtomicI64::new(match action {
            FaultAction::CloseAfterBytes(n) => n.min(i64::MAX as u64) as i64,
            _ => i64::MAX,
        }));
        let delay = match action {
            FaultAction::DelayChunks(d) => Some(d),
            _ => None,
        };
        for (from, to) in [
            (client.try_clone(), server.try_clone()),
            (server.try_clone(), client.try_clone()),
        ] {
            let (Ok(from), Ok(to)) = (from, to) else { continue };
            let budget = budget.clone();
            thread::spawn(move || relay(from, to, budget, delay));
        }
    }
}

/// Copy bytes `from → to`, honoring the shared byte budget and the
/// per-chunk delay; sever both sides once the budget runs dry.
fn relay(mut from: TcpStream, mut to: TcpStream, budget: Arc<AtomicI64>, delay: Option<Duration>) {
    let mut chunk = [0u8; 16 * 1024];
    loop {
        let n = match from.read(&mut chunk) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        if let Some(d) = delay {
            thread::sleep(d);
        }
        let before = budget.fetch_sub(n as i64, Ordering::AcqRel);
        let allowed = before.clamp(0, n as i64) as usize;
        if to.write_all(&chunk[..allowed]).is_err() {
            break;
        }
        if allowed < n {
            // Budget exhausted mid-chunk: cut the relay, both directions.
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo server: accepts connections, echoes bytes back until EOF.
    fn echo_server() -> (SocketAddr, thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind echo");
        let addr = listener.local_addr().expect("echo addr");
        let t = thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut s) = stream else { break };
                thread::spawn(move || {
                    let mut buf = [0u8; 1024];
                    loop {
                        match s.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => {
                                if s.write_all(&buf[..n]).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                });
            }
        });
        (addr, t)
    }

    fn round_trip(addr: SocketAddr, payload: &[u8]) -> std::io::Result<Vec<u8>> {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(payload)?;
        let mut got = vec![0u8; payload.len()];
        s.read_exact(&mut got)?;
        Ok(got)
    }

    #[test]
    fn clean_schedule_relays_untouched() {
        let (upstream, _t) = echo_server();
        let proxy = FaultProxy::start(upstream, vec![]).expect("proxy");
        let got = round_trip(proxy.addr(), b"hello through the relay").expect("echo");
        assert_eq!(got, b"hello through the relay");
        assert_eq!(proxy.accepted(), 1);
        proxy.shutdown();
    }

    #[test]
    fn close_after_bytes_cuts_the_stream_on_schedule() {
        let (upstream, _t) = echo_server();
        // Connection 0 dies after 8 bytes; connection 1 is clean.
        let proxy = FaultProxy::start(
            upstream,
            vec![FaultAction::CloseAfterBytes(8), FaultAction::None],
        )
        .expect("proxy");
        let err = round_trip(proxy.addr(), &[7u8; 64]).expect_err("truncated relay");
        let _ = err; // read_exact fails: EOF before 64 echoed bytes
        let got = round_trip(proxy.addr(), &[9u8; 64]).expect("clean follow-up");
        assert_eq!(got, vec![9u8; 64]);
        proxy.shutdown();
    }

    #[test]
    fn delayed_chunks_still_arrive_intact() {
        let (upstream, _t) = echo_server();
        let proxy = FaultProxy::start(
            upstream,
            vec![FaultAction::DelayChunks(Duration::from_millis(2))],
        )
        .expect("proxy");
        let got = round_trip(proxy.addr(), b"slow but correct").expect("echo");
        assert_eq!(got, b"slow but correct");
        proxy.shutdown();
    }

    #[test]
    fn partition_severs_and_heal_restores() {
        let (upstream, _t) = echo_server();
        let proxy = FaultProxy::start(upstream, vec![]).expect("proxy");
        let mut live = TcpStream::connect(proxy.addr()).expect("dial");
        live.write_all(b"warm").expect("write");
        let mut buf = [0u8; 4];
        live.read_exact(&mut buf).expect("echo before partition");

        proxy.partition();
        // The live relay is severed: the next exchange fails.
        let dead = live.write_all(&[0u8; 1024]).and_then(|_| {
            let mut b = [0u8; 1];
            live.read_exact(&mut b)
        });
        assert!(dead.is_err(), "partitioned relay must not answer");
        // New connections are refused (accepted then severed).
        assert!(round_trip(proxy.addr(), b"nope").is_err());

        proxy.heal();
        let got = round_trip(proxy.addr(), b"back").expect("healed relay");
        assert_eq!(got, b"back");
        proxy.shutdown();
    }

    #[test]
    fn seeded_schedules_are_reproducible() {
        let a = seeded_schedule(0xFA017, 32);
        let b = seeded_schedule(0xFA017, 32);
        assert_eq!(a, b);
        assert_ne!(a, seeded_schedule(0xFA018, 32), "seed must matter");
        assert!(a.iter().any(|f| *f != FaultAction::None), "mix includes faults");
    }
}
