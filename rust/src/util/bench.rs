//! Micro-benchmark harness (criterion replacement for the offline
//! environment). Used by the `rust/benches/*.rs` targets (built with
//! `harness = false`) and by the in-binary perf commands.
//!
//! Method: warmup, then timed batches until both a minimum wall time and a
//! minimum iteration count are reached; reports mean / p50 / p99 per-iteration
//! times with outlier-robust statistics.

use std::time::{Duration, Instant};

use super::stats::{mean, percentile, stddev};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name, as printed and as keyed in BENCH artifacts.
    pub name: String,
    /// Iterations actually measured.
    pub iterations: u64,
    /// Mean iteration time in nanoseconds.
    pub mean_ns: f64,
    /// Median iteration time in nanoseconds.
    pub p50_ns: f64,
    /// 99th-percentile iteration time in nanoseconds.
    pub p99_ns: f64,
    /// Iteration-time standard deviation in nanoseconds.
    pub stddev_ns: f64,
    /// Throughput in user-provided elements/iteration, if set.
    pub elems_per_iter: Option<f64>,
    /// Bytes streamed per iteration, if set — the GB/s basis, so kernel
    /// numbers are comparable across dims/rows and across PRs.
    pub bytes_per_iter: Option<f64>,
}

impl BenchResult {
    /// Elements per second, when a throughput basis was provided.
    pub fn throughput(&self) -> Option<f64> {
        self.elems_per_iter.map(|e| e / (self.mean_ns * 1e-9))
    }

    /// Millions of elements per second — the cross-bench normalized unit.
    pub fn melems_per_s(&self) -> Option<f64> {
        self.throughput().map(|t| t / 1e6)
    }

    /// Gigabytes per second, when a bytes basis was provided
    /// (bytes/ns ≡ GB/s).
    pub fn gb_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b / self.mean_ns)
    }

    /// One-line human-readable report row: mean/p50/p99 plus normalized
    /// Melems/s and (with a bytes basis) GB/s — every bench target reports
    /// through this one formatter so units stay comparable.
    pub fn row(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Melem/s", t / 1e6),
            Some(t) => format!("  {:8.2} elem/s", t),
            None => String::new(),
        };
        let gb = match self.gb_per_s() {
            Some(g) => format!("  {g:8.2} GB/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} {:>12} {:>12}  (n={}){tp}{gb}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iterations,
        )
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with configurable budget.
pub struct Bench {
    /// Time spent warming before measurement starts.
    pub warmup: Duration,
    /// Measurement budget.
    pub measure: Duration,
    /// Floor on measured iterations (overrides the time budget).
    pub min_iters: u64,
    /// Ceiling on measured iterations.
    pub max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(700),
            min_iters: 10,
            max_iters: 2_000_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// Runner with the default budget.
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-budget harness for use inside `cargo test`-adjacent smoke runs.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(80),
            min_iters: 3,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Measure `f`, preventing the result from being optimized away via
    /// `std::hint::black_box`.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_throughput(name, None, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Measure with a throughput basis (elements processed per iteration).
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        elems: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_throughput(name, Some(elems), None, move || {
            std::hint::black_box(f());
        })
    }

    /// Measure with both an element basis (Melems/s) and a bytes basis
    /// (GB/s) — the shared helper every kernel-shaped bench reports through.
    pub fn bench_gbps<T>(
        &mut self,
        name: &str,
        elems: f64,
        bytes: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_throughput(name, Some(elems), Some(bytes), move || {
            std::hint::black_box(f());
        })
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        elems: Option<f64>,
        bytes: Option<f64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // Warmup and per-iteration time estimate.
        let wstart = Instant::now();
        let mut wu_iters = 0u64;
        while wstart.elapsed() < self.warmup || wu_iters < 3 {
            f();
            wu_iters += 1;
            if wu_iters >= self.max_iters {
                break;
            }
        }
        let est_ns = (wstart.elapsed().as_nanos() as f64 / wu_iters as f64).max(1.0);

        // Choose a batch size so each sample is ≥ ~50 µs (timer noise floor).
        let batch = ((50_000.0 / est_ns).ceil() as u64).clamp(1, self.max_iters);
        let mut samples_ns: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let mstart = Instant::now();
        while (mstart.elapsed() < self.measure || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(dt);
            iters += batch;
        }

        let result = BenchResult {
            name: name.to_string(),
            iterations: iters,
            mean_ns: mean(&samples_ns),
            p50_ns: percentile(&samples_ns, 50.0),
            p99_ns: percentile(&samples_ns, 99.0),
            stddev_ns: stddev(&samples_ns),
            elems_per_iter: elems,
            bytes_per_iter: bytes,
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a report table with a title.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p99"
        );
        for r in &self.results {
            println!("{}", r.row());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let mut b = Bench::quick();
        let r = b.bench("spin", || {
            // Data-dependent multiply chain: LLVM can neither const-fold nor
            // closed-form this (unlike a sum of squares).
            let n = std::hint::black_box(1000u64);
            let mut s = 0x9E37_79B9u64;
            for i in 0..n {
                s = s.wrapping_mul(i | 1).rotate_left(7);
            }
            s
        });
        assert!(r.mean_ns > 100.0, "1000-deep multiply chain must take >100ns: {}", r.mean_ns);
        assert!(r.mean_ns < 1e7, "and well under 10ms: {}", r.mean_ns);
        assert!(r.iterations >= 3);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::quick();
        let r = b.bench_throughput("tp", 1024.0, || std::hint::black_box(3u32 * 7));
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0);
        assert!(r.gb_per_s().is_none(), "no bytes basis unless provided");
    }

    #[test]
    fn gbps_and_melems_units_consistent() {
        let mut b = Bench::quick();
        let r = b.bench_gbps("units", 1000.0, 8000.0, || std::hint::black_box(3u32 * 7));
        let gb = r.gb_per_s().unwrap();
        let me = r.melems_per_s().unwrap();
        assert!(gb > 0.0 && me > 0.0);
        // 8 bytes/elem: GB/s and Melems/s are locked together by definition
        // (1 GB/s == 125 Melems/s at 8 B/elem).
        assert!((gb * 1000.0 / 8.0 - me).abs() < me * 1e-9, "gb={gb} me={me}");
        assert!(r.row().contains("GB/s"));
    }

    #[test]
    fn report_rows_render() {
        let mut b = Bench::quick();
        b.bench("a", || 1 + 1);
        assert!(b.results()[0].row().contains("a"));
        assert!(fmt_ns(1.5e6).contains("ms"));
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(2.5e3).contains("µs"));
        assert!(fmt_ns(3.0e9).contains(" s"));
    }
}
