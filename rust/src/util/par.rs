//! Tiny data-parallel helpers on std::thread::scope (rayon replacement for
//! the offline environment). Used by the Monte Carlo harness (Fig. 7) and
//! the analog array engine, where trials are embarrassingly parallel.

/// Parallel map over `items`, preserving order. Splits into contiguous
/// chunks across up to `max_threads` OS threads (defaults to available
/// parallelism). Falls back to serial for small inputs.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    par_map_with(items, default_threads(), f)
}

/// As [`par_map`] with an explicit thread cap.
pub fn par_map_with<T, U, F>(items: &[T], max_threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let threads = max_threads.max(1).min(n.max(1));
    if threads <= 1 || n < 2 {
        return items.iter().map(&f). collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let fref = &f;
    std::thread::scope(|s| {
        let mut slots = out.as_mut_slice();
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = slots.split_at_mut(take);
            slots = tail;
            let src = &items[start..start + take];
            handles.push(s.spawn(move || {
                for (slot, item) in head.iter_mut().zip(src) {
                    *slot = Some(fref(item));
                }
            }));
            start += take;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// Parallel index map: like `par_map` over `0..n`.
pub fn par_map_idx<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i))
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map_with(&xs, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, |&x| x).is_empty());
    }

    #[test]
    fn uneven_chunks() {
        let xs: Vec<usize> = (0..7).collect();
        assert_eq!(par_map_with(&xs, 3, |&x| x), xs);
    }

    #[test]
    fn idx_variant() {
        assert_eq!(par_map_idx(4, |i| i * i), vec![0, 1, 4, 9]);
    }
}
