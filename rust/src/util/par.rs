//! Tiny data-parallel helpers on std::thread::scope (rayon replacement for
//! the offline environment). Used by the Monte Carlo harness (Fig. 7) and
//! the analog array engine, where trials are embarrassingly parallel.

/// Parallel map over `items`, preserving order. Splits into contiguous
/// chunks across up to `max_threads` OS threads (defaults to available
/// parallelism). Falls back to serial for small inputs.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    par_map_with(items, default_threads(), f)
}

/// As [`par_map`] with an explicit thread cap.
pub fn par_map_with<T, U, F>(items: &[T], max_threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let threads = max_threads.max(1).min(n.max(1));
    if threads <= 1 || n < 2 {
        return items.iter().map(&f). collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let fref = &f;
    std::thread::scope(|s| {
        let mut slots = out.as_mut_slice();
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = slots.split_at_mut(take);
            slots = tail;
            let src = &items[start..start + take];
            handles.push(s.spawn(move || {
                for (slot, item) in head.iter_mut().zip(src) {
                    *slot = Some(fref(item));
                }
            }));
            start += take;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// Parallel index map: like `par_map` over `0..n`.
pub fn par_map_idx<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i))
}

/// Parallel in-place for-each over a mutable slice: `f(index, &mut item)`
/// runs once per item, split into contiguous chunks across up to the default
/// thread count. Used by the tile manager to fill per-slot top-k buffers
/// across tile×batch work items without collecting intermediate vectors.
pub fn par_for_each_mut<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = default_threads().max(1).min(n.max(1));
    if threads <= 1 || n < 2 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let fref = &f;
    std::thread::scope(|s| {
        let mut rest = items;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = start;
            handles.push(s.spawn(move || {
                for (off, item) in head.iter_mut().enumerate() {
                    fref(base + off, item);
                }
            }));
            start += take;
        }
        for h in handles {
            h.join().expect("par_for_each_mut worker panicked");
        }
    });
}

/// Default worker count: the machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map_with(&xs, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, |&x| x).is_empty());
    }

    #[test]
    fn uneven_chunks() {
        let xs: Vec<usize> = (0..7).collect();
        assert_eq!(par_map_with(&xs, 3, |&x| x), xs);
    }

    #[test]
    fn idx_variant() {
        assert_eq!(par_map_idx(4, |i| i * i), vec![0, 1, 4, 9]);
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let mut xs: Vec<u64> = (0..1000).collect();
        par_for_each_mut(&mut xs, |i, x| {
            assert_eq!(*x, i as u64, "index matches item");
            *x *= 3;
        });
        assert!(xs.iter().enumerate().all(|(i, &x)| x == 3 * i as u64));
    }

    #[test]
    fn for_each_mut_handles_empty_and_single() {
        let mut none: Vec<u8> = vec![];
        par_for_each_mut(&mut none, |_, _| {});
        let mut one = vec![7u8];
        par_for_each_mut(&mut one, |_, x| *x += 1);
        assert_eq!(one, vec![8]);
    }
}
