//! Lightweight statistics helpers used by the benches, the Monte Carlo
//! harness (Fig. 7) and the coordinator metrics.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation; 0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0,100]. NaN-free inputs assumed.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        RunningStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold in one sample (Welford update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of the samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Unbiased sample variance (0 below two samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Fold another accumulator into this one (parallel Welford combine:
    /// Chan et al.'s pairwise update), as if every sample pushed into
    /// `other` had been pushed here.
    pub fn merge_from(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        self.mean += d * n2 / (n1 + n2);
        self.m2 += other.m2 + d * d * n1 * n2 / (n1 + n2);
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Reconstruct from raw parts (the wire metrics codec ships these).
    pub fn from_raw(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        if n == 0 {
            return RunningStats::new();
        }
        RunningStats { n, mean, m2, min, max }
    }

    /// Raw parts `(n, mean, m2, min, max)` for serialization; the inverse
    /// of [`RunningStats::from_raw`].
    pub fn raw(&self) -> (u64, f64, f64, f64, f64) {
        (self.n, self.mean, self.m2, self.min, self.max)
    }
}

/// Fixed-bucket latency histogram (log-spaced), used by coordinator metrics.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds in the measured unit (e.g. microseconds).
    bounds: Vec<f64>,
    counts: Vec<u64>,
    stats: RunningStats,
}

impl Histogram {
    /// Log-spaced buckets from `lo` to `hi` (inclusive upper bound per bucket,
    /// final overflow bucket appended).
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 1);
        let ratio = (hi / lo).powf(1.0 / n as f64);
        let bounds: Vec<f64> = (0..=n).map(|i| lo * ratio.powi(i as i32)).collect();
        let len = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; len], stats: RunningStats::new() }
    }

    /// Record one sample into its bucket and the running summary.
    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.iter().position(|&b| x <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.stats.push(x);
    }

    /// Fold another histogram with the *same bucket layout* into this one,
    /// as if every sample recorded there had been recorded here — the exact
    /// cross-lane quantile merge (buckets are fixed and aligned, so adding
    /// counts loses nothing the single-lane histogram had). Panics if the
    /// bucket bounds differ (different construction parameters).
    pub fn merge_from(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "Histogram::merge_from requires identical bucket layouts"
        );
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.stats.merge_from(&other.stats);
    }

    /// Per-bucket counts (one per bound, plus the trailing overflow bucket).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// The summary accumulator behind [`Histogram::mean`]/`max`/quantile
    /// endpoints (for serialization alongside [`Histogram::counts`]).
    pub fn stats(&self) -> &RunningStats {
        &self.stats
    }

    /// Rebuild a histogram from serialized parts. The caller supplies the
    /// same construction parameters (`lo`/`hi`/`n` of
    /// [`Histogram::log_spaced`]); `counts` must match that layout's bucket
    /// count or the reconstruction is rejected with `None`.
    pub fn from_parts(lo: f64, hi: f64, n: usize, counts: &[u64], stats: RunningStats) -> Option<Histogram> {
        let mut h = Histogram::log_spaced(lo, hi, n);
        if counts.len() != h.counts.len() {
            return None;
        }
        h.counts.copy_from_slice(counts);
        h.stats = stats;
        Some(h)
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    /// Mean of the recorded samples.
    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    /// Largest recorded sample.
    pub fn max(&self) -> f64 {
        self.stats.max()
    }

    /// Approximate quantile from bucket boundaries. `q = 0` returns the
    /// tracked minimum (the bucket scan's target count would be 0 there, so
    /// the very first — possibly empty — bucket's upper bound would win
    /// regardless of the data).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.stats.min();
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { self.stats.max() };
            }
        }
        self.stats.max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn running_stats_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0];
        let mut rs = RunningStats::new();
        for &x in &xs {
            rs.push(x);
        }
        assert!((rs.mean() - mean(&xs)).abs() < 1e-12);
        assert!((rs.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(rs.min(), 1.0);
        assert_eq!(rs.max(), 9.0);
    }

    /// Regression: with only large values recorded, `quantile(0.0)` used to
    /// return the first bucket's upper bound (the target count is 0, so the
    /// scan stopped immediately); it must return the tracked minimum.
    #[test]
    fn quantile_zero_returns_min_not_first_bucket() {
        let mut h = Histogram::log_spaced(1.0, 1000.0, 30);
        h.record(500.0);
        h.record(900.0);
        assert_eq!(h.quantile(0.0), 500.0);
        assert!(h.quantile(1.0) >= 900.0);
        // Empty histogram stays at the 0.0 sentinel.
        let empty = Histogram::log_spaced(1.0, 1000.0, 30);
        assert_eq!(empty.quantile(0.0), 0.0);
    }

    /// Merging two histograms must be indistinguishable from recording
    /// every sample into one — the property the cross-shard percentile
    /// aggregation relies on.
    #[test]
    fn histogram_merge_equals_single_recording() {
        let mut a = Histogram::log_spaced(1.0, 1000.0, 30);
        let mut b = Histogram::log_spaced(1.0, 1000.0, 30);
        let mut all = Histogram::log_spaced(1.0, 1000.0, 30);
        for i in 1..=500 {
            let x = (i * 7 % 990 + 1) as f64;
            if i % 3 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            all.record(x);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.counts(), all.counts());
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), all.quantile(q), "quantile {q}");
        }
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert_eq!(a.max(), all.max());
        // Merging an empty histogram is a no-op.
        let before = a.count();
        a.merge_from(&Histogram::log_spaced(1.0, 1000.0, 30));
        assert_eq!(a.count(), before);
    }

    #[test]
    fn running_stats_merge_matches_combined_stream() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut left = RunningStats::new();
        let mut right = RunningStats::new();
        let mut whole = RunningStats::new();
        for (i, &x) in xs.iter().enumerate() {
            if i < 3 {
                left.push(x);
            } else {
                right.push(x);
            }
            whole.push(x);
        }
        left.merge_from(&right);
        assert_eq!(left.count(), whole.count());
        assert!((left.mean() - whole.mean()).abs() < 1e-12);
        assert!((left.stddev() - whole.stddev()).abs() < 1e-12);
        assert_eq!(left.min(), whole.min());
        assert_eq!(left.max(), whole.max());
        // Raw round trip.
        let (n, mean, m2, min, max) = whole.raw();
        let back = RunningStats::from_raw(n, mean, m2, min, max);
        assert_eq!(back.count(), whole.count());
        assert!((back.variance() - whole.variance()).abs() < 1e-12);
    }

    #[test]
    fn histogram_from_parts_round_trips() {
        let mut h = Histogram::log_spaced(0.5, 10_000_000.0, 120);
        for x in [1.0, 50.0, 900.0, 1e6] {
            h.record(x);
        }
        let back = Histogram::from_parts(
            0.5,
            10_000_000.0,
            120,
            h.counts(),
            h.stats().clone(),
        )
        .expect("layout matches");
        assert_eq!(back.counts(), h.counts());
        assert_eq!(back.quantile(0.5), h.quantile(0.5));
        assert_eq!(back.mean(), h.mean());
        // Wrong layout is rejected, not silently misbinned.
        assert!(Histogram::from_parts(0.5, 100.0, 10, h.counts(), h.stats().clone()).is_none());
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::log_spaced(1.0, 1000.0, 30);
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99, "p50 {p50} p99 {p99}");
        assert!(p50 > 300.0 && p50 < 700.0, "p50 {p50}");
        assert!(h.max() == 1000.0);
    }
}
