//! Poison-recovering lock helpers for serving-path state.
//!
//! `std`'s mutexes poison when a holder panics, and the idiomatic
//! `lock().unwrap()` turns one panicked thread into a cascade that takes the
//! whole process down. For *recoverable* state — metrics counters, the
//! batcher queue, a remote connection's request table — that cascade is the
//! wrong trade: each of those structures is valid after any partial update
//! (counters may be off by one sample; the connection layer has its own
//! explicit poisoning protocol that fails pending requests with typed
//! errors). These helpers recover the guard and keep serving.
//!
//! They are deliberately **not** used for the tile-store epoch lock
//! ([`crate::coordinator::TileManager`]): a writer that panicked mid-commit
//! may have left a torn tile set, and serving wrong similarity results is
//! strictly worse than crashing. That lock keeps the panicking `unwrap`,
//! with a `// lint: allow(no-panic)` waiver documenting exactly this choice.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv`, recovering the reacquired guard from poisoning.
pub fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv` with a timeout, recovering the reacquired guard from
/// poisoning.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn wait_timeout_recover_times_out_normally() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let (_g, res) = wait_timeout_recover(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
