//! Poison-recovering lock helpers, named lock classes, and the runtime
//! lockdep rail.
//!
//! # Poison policy
//!
//! `std`'s mutexes poison when a holder panics, and the idiomatic
//! `lock().unwrap()` turns one panicked thread into a cascade that takes the
//! whole process down. For *recoverable* state — metrics counters, the
//! batcher queue, a remote connection's request table — that cascade is the
//! wrong trade: each of those structures is valid after any partial update
//! (counters may be off by one sample; the connection layer has its own
//! explicit poisoning protocol that fails pending requests with typed
//! errors). [`lock_recover`] and the [`TrackedMutex`] wrapper recover the
//! guard and keep serving.
//!
//! The recovery policy is deliberately **not** applied to the tile-store
//! epoch lock ([`crate::coordinator::TileManager`]): a writer that panicked
//! mid-commit may have left a torn tile set, and serving wrong similarity
//! results is strictly worse than crashing. [`TrackedRwLock`] therefore
//! returns the raw [`LockResult`], and that call site keeps its panicking
//! `unwrap` with a `// lint: allow(no-panic)` waiver documenting exactly
//! this choice.
//!
//! # Lock classes and lockdep
//!
//! Every long-lived lock in the serving stack belongs to a named
//! [`LockClass`] with a rank in the declared partial order ([`lock_order`]).
//! Locks must be acquired in ascending rank; the table is the single source
//! of truth for both rails that enforce it:
//!
//! * **Runtime** — under `cfg(debug_assertions)` or `COSIME_LOCKDEP=1`,
//!   every tracked acquisition records an edge from the top of the current
//!   thread's held stack into a global lock-order graph. The first edge that
//!   closes a cycle panics immediately — on *any* interleaving that
//!   exhibits the inverted order, not just the one that actually deadlocks —
//!   naming both acquisition sites and the previously recorded path.
//! * **Static** — `cosime lint`'s `lock-order` rule reads the same table
//!   out of this file and flags a lower-ranked acquisition textually inside
//!   a region holding a higher-ranked class.
//!
//! Same-class nesting (e.g. recursive read locks) is not tracked: the graph
//! records inter-class edges only, so a self-deadlock on one class is out of
//! scope for this rail.
//!
//! Tracked acquisitions are also scheduling yield points for the
//! deterministic interleaving harness ([`crate::util::sched`]).

use std::cell::RefCell;
use std::panic::Location;
use std::sync::{
    Condvar, LockResult, Mutex, MutexGuard, OnceLock, PoisonError, RwLock, WaitTimeoutResult,
};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Poison-recovering helpers (the original rail; the tracked wrappers build
// on these).

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv`, recovering the reacquired guard from poisoning.
pub fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv` with a timeout, recovering the reacquired guard from
/// poisoning.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// The declared lock order.

/// One row of the declared lock-order table: the class named `name` sits at
/// `rank` in the partial order and is keyed in source by the struct field
/// `field` (field names are unique across the tree on purpose — the static
/// `lock-order` lint rule matches acquisitions textually by field).
pub struct LockOrderSpec {
    /// Stable class name, `area.role` style (e.g. `"tiles.store"`).
    pub name: &'static str,
    /// Position in the partial order; acquire in ascending rank.
    pub rank: u32,
    /// The struct field holding the lock, unique across the tree.
    pub field: &'static str,
}

/// The intended partial order over every tracked lock in the serving stack,
/// outermost (acquired first) to innermost. Keep this table, the
/// [`LockClass`] statics below, and `DESIGN.md` §Static analysis in sync —
/// a unit test pins the statics to the table.
///
/// Plain literal data (no references to the statics): `const` items cannot
/// name `static`s, and the lint wants a table it can read back textually.
pub const LOCK_ORDER: &[LockOrderSpec] = &[
    LockOrderSpec { name: "service.writer", rank: 10, field: "writer" },
    LockOrderSpec { name: "tiles.store", rank: 20, field: "tiles" },
    LockOrderSpec { name: "service.log", rank: 30, field: "log" },
    LockOrderSpec { name: "batcher.queue", rank: 40, field: "queue" },
    LockOrderSpec { name: "router.health", rank: 50, field: "healthy" },
    LockOrderSpec { name: "remote.conn", rank: 60, field: "conn" },
    LockOrderSpec { name: "fault.live", rank: 70, field: "live" },
    LockOrderSpec { name: "metrics.counters", rank: 80, field: "counters" },
];

/// The declared lock-order table (see [`LOCK_ORDER`]).
pub fn lock_order() -> &'static [LockOrderSpec] {
    LOCK_ORDER
}

/// A named lock class. Identity is the `&'static LockClass` pointer: every
/// lock wrapping the same class static shares one node in the lock-order
/// graph.
pub struct LockClass {
    /// Stable class name, matching a [`LOCK_ORDER`] row.
    pub name: &'static str,
    /// Declared rank, matching the same row.
    pub rank: u32,
}

/// The write path's verify-loop state ([`crate::coordinator::AmService`]).
pub static SERVICE_WRITER: LockClass = LockClass { name: "service.writer", rank: 10 };
/// The tile-store epoch lock ([`crate::coordinator::TileManager`]).
pub static TILES_STORE: LockClass = LockClass { name: "tiles.store", rank: 20 };
/// The replication ring buffer ([`crate::coordinator::AmService`]).
pub static SERVICE_LOG: LockClass = LockClass { name: "service.log", rank: 30 };
/// The dynamic batcher's submission queue.
pub static BATCHER_QUEUE: LockClass = LockClass { name: "batcher.queue", rank: 40 };
/// The router's per-shard health map ([`crate::server::shard`]).
pub static ROUTER_HEALTH: LockClass = LockClass { name: "router.health", rank: 50 };
/// A remote backend's shared connection slot — its in-flight completion
/// FIFO ([`crate::server::RemoteBackend`]).
pub static REMOTE_CONN: LockClass = LockClass { name: "remote.conn", rank: 60 };
/// The fault proxy's live-connection list ([`crate::util::fault`]).
pub static FAULT_LIVE: LockClass = LockClass { name: "fault.live", rank: 70 };
/// The metrics counter block — innermost, so any path may record.
pub static METRICS_COUNTERS: LockClass = LockClass { name: "metrics.counters", rank: 80 };

// ---------------------------------------------------------------------------
// Runtime lockdep: the global lock-order graph.

/// Is the runtime lockdep rail active? Memoized once per process: on under
/// `cfg(debug_assertions)`, or in any build when `COSIME_LOCKDEP=1`.
pub fn lockdep_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        cfg!(debug_assertions)
            || std::env::var("COSIME_LOCKDEP").map(|v| v == "1").unwrap_or(false)
    })
}

/// One recorded acquisition-order edge: some thread acquired `to` while
/// holding `from`, at the recorded sites.
struct DepEdge {
    from: &'static LockClass,
    to: &'static LockClass,
    from_site: &'static Location<'static>,
    to_site: &'static Location<'static>,
}

/// The global lock-order graph. A plain mutex (accessed through
/// [`lock_recover`], never tracked) so lockdep cannot recurse into itself.
static DEPS: Mutex<Vec<DepEdge>> = Mutex::new(Vec::new());

#[derive(Clone, Copy)]
struct HeldLock {
    class: &'static LockClass,
    site: &'static Location<'static>,
}

thread_local! {
    /// This thread's current acquisition stack (tracked locks only).
    static HELD: RefCell<Vec<HeldLock>> = const { RefCell::new(Vec::new()) };
}

/// Depth-first search for a recorded path `from → … → to`; on success
/// `path` holds the witnessing edges.
fn reaches<'a>(
    deps: &'a [DepEdge],
    from: &'static LockClass,
    to: &'static LockClass,
    visited: &mut Vec<*const LockClass>,
    path: &mut Vec<&'a DepEdge>,
) -> bool {
    for e in deps {
        if !std::ptr::eq(e.from, from) || visited.contains(&(e.to as *const LockClass)) {
            continue;
        }
        visited.push(e.to);
        path.push(e);
        if std::ptr::eq(e.to, to) || reaches(deps, e.to, to, visited, path) {
            return true;
        }
        path.pop();
    }
    false
}

/// Record the would-be edge `held_top → class` and panic if it closes a
/// cycle. Runs *before* the inner lock is touched and before the held stack
/// is pushed, so a lockdep panic never poisons the guarded state and never
/// leaves a stale held entry.
fn before_acquire(class: &'static LockClass, site: &'static Location<'static>) {
    if !lockdep_enabled() {
        return;
    }
    let top = HELD.with(|h| h.borrow().last().copied());
    let Some(top) = top else {
        HELD.with(|h| h.borrow_mut().push(HeldLock { class, site }));
        return;
    };
    // Same-class nesting (read recursion) is out of scope — see module docs.
    if !std::ptr::eq(top.class, class) {
        let mut deps = lock_recover(&DEPS);
        let known = deps
            .iter()
            .any(|e| std::ptr::eq(e.from, top.class) && std::ptr::eq(e.to, class));
        if !known {
            let mut visited = vec![class as *const LockClass];
            let mut path = Vec::new();
            if reaches(&deps, class, top.class, &mut visited, &mut path) {
                let mut msg = format!(
                    "lockdep: lock-order cycle: acquiring \"{}\" (rank {}) at {site} while \
                     holding \"{}\" (rank {}, acquired at {}); previously recorded order:",
                    class.name, class.rank, top.class.name, top.class.rank, top.site,
                );
                for e in &path {
                    msg.push_str(&format!(
                        "\n  \"{}\" then \"{}\" ({} then {})",
                        e.from.name, e.to.name, e.from_site, e.to_site
                    ));
                }
                // `path` borrows the graph; release both before unwinding so
                // the panic never poisons DEPS.
                drop(path);
                drop(deps);
                panic!("{msg}");
            }
            deps.push(DepEdge { from: top.class, to: class, from_site: top.site, to_site: site });
        }
        drop(deps);
    }
    HELD.with(|h| h.borrow_mut().push(HeldLock { class, site }));
}

/// Pop the most recent held entry for `class` (most-recent-match, so
/// out-of-order guard drops and same-class nesting stay balanced).
fn after_release(class: &'static LockClass) {
    if !lockdep_enabled() {
        return;
    }
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|e| std::ptr::eq(e.class, class)) {
            held.remove(pos);
        }
    });
}

// ---------------------------------------------------------------------------
// Tracked wrappers.

/// A poison-*recovering* mutex bound to a [`LockClass`]:
/// [`TrackedMutex::lock`] participates in the lockdep graph and the
/// interleaving harness, then recovers the guard exactly like
/// [`lock_recover`].
pub struct TrackedMutex<T> {
    class: &'static LockClass,
    inner: Mutex<T>,
}

impl<T> TrackedMutex<T> {
    /// Wrap `value` in a mutex belonging to `class`.
    pub fn new(class: &'static LockClass, value: T) -> TrackedMutex<T> {
        TrackedMutex { class, inner: Mutex::new(value) }
    }

    /// Lock, recovering from poison. The acquisition is a sched yield point
    /// and is checked against the lock-order graph before blocking.
    #[track_caller]
    pub fn lock(&self) -> TrackedMutexGuard<'_, T> {
        let site = Location::caller();
        crate::util::sched::yield_point();
        before_acquire(self.class, site);
        TrackedMutexGuard { guard: Some(lock_recover(&self.inner)), class: self.class }
    }

    /// Non-blocking lock attempt, recovering from poison. Registers on the
    /// held stack when it succeeds (later acquisitions are checked against
    /// it) but records no order edge itself — a `try_lock` cannot deadlock.
    #[track_caller]
    pub fn try_lock(&self) -> Option<TrackedMutexGuard<'_, T>> {
        let site = Location::caller();
        let guard = match self.inner.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => return None,
        };
        if lockdep_enabled() {
            HELD.with(|h| h.borrow_mut().push(HeldLock { class: self.class, site }));
        }
        Some(TrackedMutexGuard { guard: Some(guard), class: self.class })
    }
}

/// Guard returned by [`TrackedMutex::lock`]; pops the lockdep held stack on
/// drop.
pub struct TrackedMutexGuard<'a, T> {
    /// Present from construction until drop (or until a condvar wait takes
    /// it); `Option` only so [`wait_tracked`] can move the inner guard out.
    guard: Option<MutexGuard<'a, T>>,
    class: &'static LockClass,
}

impl<T> std::ops::Deref for TrackedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present until drop")
    }
}

impl<T> std::ops::DerefMut for TrackedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard present until drop")
    }
}

impl<T> Drop for TrackedMutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.guard.take() {
            drop(g);
            after_release(self.class);
        }
    }
}

/// Block on `cv` until notified, releasing and reacquiring a tracked guard.
/// The lockdep held entry is retained across the wait (the thread still
/// *logically* owns the slot: it will reacquire before running), so the
/// reacquisition records no fresh edge.
pub fn wait_tracked<'a, T>(
    cv: &Condvar,
    mut g: TrackedMutexGuard<'a, T>,
) -> TrackedMutexGuard<'a, T> {
    let class = g.class;
    let inner = g.guard.take().expect("guard present until drop");
    std::mem::forget(g); // keep the held entry across the wait
    TrackedMutexGuard { guard: Some(wait_recover(cv, inner)), class }
}

/// [`wait_tracked`] with a timeout.
pub fn wait_timeout_tracked<'a, T>(
    cv: &Condvar,
    mut g: TrackedMutexGuard<'a, T>,
    dur: Duration,
) -> (TrackedMutexGuard<'a, T>, WaitTimeoutResult) {
    let class = g.class;
    let inner = g.guard.take().expect("guard present until drop");
    std::mem::forget(g);
    let (inner, res) = wait_timeout_recover(cv, inner, dur);
    (TrackedMutexGuard { guard: Some(inner), class }, res)
}

/// A poison-*propagating* reader-writer lock bound to a [`LockClass`]:
/// acquisitions participate in lockdep and the interleaving harness, but the
/// raw [`LockResult`] is returned so the caller keeps std's poison semantics
/// (the tile-store policy — see the module docs).
pub struct TrackedRwLock<T> {
    class: &'static LockClass,
    inner: RwLock<T>,
}

impl<T> TrackedRwLock<T> {
    /// Wrap `value` in a reader-writer lock belonging to `class`.
    pub fn new(class: &'static LockClass, value: T) -> TrackedRwLock<T> {
        TrackedRwLock { class, inner: RwLock::new(value) }
    }

    /// Shared-lock, propagating poison like [`RwLock::read`].
    #[track_caller]
    pub fn read(&self) -> LockResult<TrackedReadGuard<'_, T>> {
        let site = Location::caller();
        crate::util::sched::yield_point();
        before_acquire(self.class, site);
        match self.inner.read() {
            Ok(g) => Ok(TrackedReadGuard { guard: Some(g), class: self.class }),
            Err(p) => Err(PoisonError::new(TrackedReadGuard {
                guard: Some(p.into_inner()),
                class: self.class,
            })),
        }
    }

    /// Exclusive-lock, propagating poison like [`RwLock::write`].
    #[track_caller]
    pub fn write(&self) -> LockResult<TrackedWriteGuard<'_, T>> {
        let site = Location::caller();
        crate::util::sched::yield_point();
        before_acquire(self.class, site);
        match self.inner.write() {
            Ok(g) => Ok(TrackedWriteGuard { guard: Some(g), class: self.class }),
            Err(p) => Err(PoisonError::new(TrackedWriteGuard {
                guard: Some(p.into_inner()),
                class: self.class,
            })),
        }
    }
}

/// Shared guard returned by [`TrackedRwLock::read`].
pub struct TrackedReadGuard<'a, T> {
    guard: Option<std::sync::RwLockReadGuard<'a, T>>,
    class: &'static LockClass,
}

impl<T> std::ops::Deref for TrackedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present until drop")
    }
}

impl<T> Drop for TrackedReadGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.guard.take() {
            drop(g);
            after_release(self.class);
        }
    }
}

/// Exclusive guard returned by [`TrackedRwLock::write`].
pub struct TrackedWriteGuard<'a, T> {
    guard: Option<std::sync::RwLockWriteGuard<'a, T>>,
    class: &'static LockClass,
}

impl<T> std::ops::Deref for TrackedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present until drop")
    }
}

impl<T> std::ops::DerefMut for TrackedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard present until drop")
    }
}

impl<T> Drop for TrackedWriteGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.guard.take() {
            drop(g);
            after_release(self.class);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn wait_timeout_recover_times_out_normally() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let (_g, res) = wait_timeout_recover(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }

    /// The pure-literal table and the class statics must agree: every
    /// static's (name, rank) pair appears in [`LOCK_ORDER`], names/fields
    /// are unique, and ranks are strictly ascending.
    #[test]
    fn lock_order_table_matches_class_statics() {
        let statics: &[&LockClass] = &[
            &SERVICE_WRITER,
            &TILES_STORE,
            &SERVICE_LOG,
            &BATCHER_QUEUE,
            &ROUTER_HEALTH,
            &REMOTE_CONN,
            &FAULT_LIVE,
            &METRICS_COUNTERS,
        ];
        assert_eq!(statics.len(), LOCK_ORDER.len(), "one static per table row");
        for class in statics {
            assert!(
                LOCK_ORDER.iter().any(|s| s.name == class.name && s.rank == class.rank),
                "class {} (rank {}) missing from LOCK_ORDER",
                class.name,
                class.rank
            );
        }
        for w in lock_order().windows(2) {
            assert!(w[0].rank < w[1].rank, "ranks strictly ascending: {}", w[1].name);
        }
        for (i, a) in LOCK_ORDER.iter().enumerate() {
            for b in &LOCK_ORDER[i + 1..] {
                assert_ne!(a.name, b.name, "duplicate class name");
                assert_ne!(a.field, b.field, "field keys must stay unique for the lint");
            }
        }
    }

    #[test]
    fn tracked_mutex_recovers_poison_and_balances_held_stack() {
        static STORM: LockClass = LockClass { name: "test.storm", rank: 9_000 };
        let m = Arc::new(TrackedMutex::new(&STORM, 7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the tracked lock");
        })
        .join();
        assert_eq!(*m.lock(), 7, "tracked lock recovers the guard");
        *m.lock() = 8;
        assert_eq!(*m.lock(), 8);
        HELD.with(|h| assert!(h.borrow().is_empty(), "held stack balanced after drops"));
    }

    #[test]
    fn tracked_rwlock_propagates_poison() {
        static EPOCH: LockClass = LockClass { name: "test.epoch", rank: 9_010 };
        let l = Arc::new(TrackedRwLock::new(&EPOCH, 3u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("panic mid-commit");
        })
        .join();
        assert!(l.read().is_err(), "poison must propagate to readers");
        assert!(l.write().is_err(), "poison must propagate to writers");
        // The guard is still reachable through the error for explicit
        // recovery, matching std semantics.
        assert_eq!(*l.read().unwrap_or_else(PoisonError::into_inner), 3);
        HELD.with(|h| assert!(h.borrow().is_empty(), "held stack balanced after drops"));
    }

    /// The acceptance fixture: acquire A-then-B, release, then B-then-A.
    /// Lockdep must panic on the second pattern's inner acquisition, naming
    /// both classes and both acquisition sites, before anything deadlocks.
    #[test]
    fn lockdep_detects_inverted_order() {
        if !lockdep_enabled() {
            // Release build without COSIME_LOCKDEP: the rail is off.
            return;
        }
        static INV_A: LockClass = LockClass { name: "test.inverted-a", rank: 9_020 };
        static INV_B: LockClass = LockClass { name: "test.inverted-b", rank: 9_021 };
        let a = TrackedMutex::new(&INV_A, ());
        let b = TrackedMutex::new(&INV_B, ());
        {
            let _ga = a.lock();
            let _gb = b.lock(); // records test.inverted-a -> test.inverted-b
        }
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gb = b.lock();
            let _ga = a.lock(); // closes the cycle: must panic here
        }))
        .expect_err("inverted acquisition must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("lockdep"), "panic is a lockdep report: {msg}");
        assert!(msg.contains("test.inverted-a"), "names the held class: {msg}");
        assert!(msg.contains("test.inverted-b"), "names the acquiring class: {msg}");
        assert!(msg.contains("sync.rs"), "names both acquisition sites: {msg}");
        HELD.with(|h| assert!(h.borrow().is_empty(), "held stack balanced after the panic"));
    }

    /// Tracked condvar waits keep the held entry across the sleep and stay
    /// balanced after the guard finally drops.
    #[test]
    fn wait_timeout_tracked_round_trips_the_guard() {
        static WAITER: LockClass = LockClass { name: "test.waiter", rank: 9_030 };
        let m = TrackedMutex::new(&WAITER, 5u32);
        let cv = Condvar::new();
        let g = m.lock();
        let (g, res) = wait_timeout_tracked(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
        assert_eq!(*g, 5);
        drop(g);
        HELD.with(|h| assert!(h.borrow().is_empty(), "held stack balanced after the wait"));
    }
}
