//! Property-based testing helper (proptest replacement for the offline
//! environment): runs a property over many seeded random cases and, on
//! failure, reports the failing case seed so it can be replayed.

use super::rng::Rng;

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropFailure {
    /// 0-based case index that failed.
    pub case: usize,
    /// Seed that reproduces the failing case.
    pub seed: u64,
    /// What the property reported.
    pub message: String,
}

/// Run `cases` random trials of `property`. The property receives a
/// deterministic per-case RNG; return `Err(msg)` to fail. Panics with the
/// replayable seed on the first failure.
pub fn check<F>(name: &str, cases: usize, base_seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Some(f) = check_quiet(cases, base_seed, &mut property) {
        panic!(
            "property '{name}' failed on case {}/{cases} (replay seed {}): {}",
            f.case, f.seed, f.message
        );
    }
}

/// Non-panicking variant; returns the first failure if any.
pub fn check_quiet<F>(cases: usize, base_seed: u64, property: &mut F) -> Option<PropFailure>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(message) = property(&mut rng) {
            return Some(PropFailure { case, seed, message });
        }
    }
    None
}

/// Convenience: assert-like helper producing a property error.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, 1, |rng| {
            let (a, b) = (rng.f64(), rng.f64());
            prop_assert!((a + b - (b + a)).abs() < 1e-15, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    fn failing_property_reports_case() {
        let f = check_quiet(100, 2, &mut |rng: &mut Rng| {
            let x = rng.f64();
            if x > 0.9 {
                Err(format!("x too big: {x}"))
            } else {
                Ok(())
            }
        });
        let f = f.expect("should fail within 100 cases");
        assert!(f.message.contains("too big"));
        // Replay the reported seed: must reproduce.
        let mut rng = Rng::seed_from_u64(f.seed);
        assert!(rng.f64() > 0.9);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn check_panics_with_seed() {
        check("always-fails", 3, 3, |_| Err("nope".into()));
    }
}
