//! Minimal JSON parser/serializer, written from scratch for the offline
//! environment (no serde). Covers the full JSON grammar; used to read the
//! AOT artifact manifest written by `python/compile/aot.py` and to dump
//! experiment results under `results/`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always stored as f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// What the parser expected or found.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors ------------------------------------------------------

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer index, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- construction helpers -------------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from any iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Wrap a number.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Wrap a string (copied).
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// An array of numbers from a slice.
    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- parse ------------------------------------------------------------

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        out.push(
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    out.push(ch);
                    let _ = c;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---- serialize -------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s, None, 0);
        f.write_str(&s)
    }
}

impl Json {
    fn write_into(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    it.write_into(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    escape_into(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write_into(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_into(&mut s, None, 0);
        s
    }

    /// Pretty (2-space) serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_into(&mut s, Some(2), 0);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"cosime","dims":[256,1024],"ok":true,"eps":0.5,"none":null}"#;
        let v = Json::parse(src).unwrap();
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A 😀"));
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 6, "{e}");
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo — ωορλδ\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ωορλδ"));
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
