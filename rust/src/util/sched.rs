//! Seeded deterministic-interleaving harness for concurrency tests.
//!
//! [`run`] spawns N worker closures and steps them under a seeded
//! permutation schedule: every worker gates at each **yield point** —
//! injected automatically at every tracked-lock acquisition
//! ([`crate::util::sync::TrackedMutex`] / [`TrackedRwLock`]), or placed
//! explicitly with [`yield_point`] — and a coordinator grants one seeded
//! pseudo-random waiting worker at a time. The grant sequence is returned
//! as a trace, so a failing interleaving replays from its seed alone — the
//! same determinism contract as the fault proxy
//! ([`crate::util::fault::FaultProxy`]).
//!
//! # Determinism contract
//!
//! The schedule is deterministic *up to genuine blocking*: a granted worker
//! that blocks on a real lock (or on unscheduled helper threads, e.g. a
//! service's worker pool) is given a quiescence window, after which the
//! coordinator grants another waiting worker so the system can make
//! progress. Scenarios whose workers only synchronize through tracked locks
//! and yield points replay exactly; scenarios that block on free-running
//! threads replay the same *decisions* but may interleave the blocked
//! stretches differently. A watchdog aborts the schedule (naming the seed
//! and per-worker states) if nothing transitions for several seconds —
//! a genuine deadlock in the code under test.
//!
//! Threads not spawned by [`run`] are unaffected: [`yield_point`] is a
//! no-op on unregistered threads, so a scenario can drive a full serving
//! stack whose internal worker pool runs freely.
//!
//! [`TrackedRwLock`]: crate::util::sync::TrackedRwLock

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::sync::{lock_recover, wait_recover, wait_timeout_recover};

/// How long the coordinator waits for the last-granted worker to reach its
/// next yield point before overlapping a second grant (see the determinism
/// contract in the module docs).
const QUIESCENCE: Duration = Duration::from_millis(100);

/// No worker transition for this long aborts the schedule: the code under
/// test has genuinely deadlocked (lockdep should have caught the inversion
/// first — this is the backstop).
const WATCHDOG: Duration = Duration::from_secs(5);

/// One scheduled worker closure.
pub type Worker<'env> = Box<dyn FnOnce() + Send + 'env>;

type PanicPayload = Box<dyn std::any::Any + Send>;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum WState {
    /// Granted (or between yield points); the worker owns its step.
    Running,
    /// Parked at a yield point, waiting for a grant.
    AtYield,
    /// Body returned (or panicked — the payload is re-thrown after join).
    Done,
}

struct SchedState {
    workers: Vec<WState>,
    /// Bumped on every transition; the coordinator's progress clock.
    version: u64,
    /// Watchdog fired: all gates become pass-through so threads can drain.
    abort: bool,
}

struct SchedInner {
    state: Mutex<SchedState>,
    cv: Condvar,
}

thread_local! {
    /// The scheduler this thread is registered with, if spawned by [`run`].
    static CURRENT: RefCell<Option<(Arc<SchedInner>, usize)>> = const { RefCell::new(None) };
}

/// Gate the current thread until the scheduler grants it the next step.
/// No-op on threads not spawned by [`run`] (and after a watchdog abort),
/// so library code can call this unconditionally — the tracked locks in
/// [`crate::util::sync`] do.
pub fn yield_point() {
    let cur = CURRENT.with(|c| c.borrow().clone());
    if let Some((inner, i)) = cur {
        inner.pause(i);
    }
}

impl SchedInner {
    /// Park worker `i` at a yield point until granted.
    fn pause(&self, i: usize) {
        let mut st = lock_recover(&self.state);
        if st.abort {
            return;
        }
        st.workers[i] = WState::AtYield;
        st.version += 1;
        self.cv.notify_all();
        while st.workers[i] != WState::Running && !st.abort {
            st = wait_recover(&self.cv, st);
        }
    }

    fn finish(&self, i: usize) {
        let mut st = lock_recover(&self.state);
        st.workers[i] = WState::Done;
        st.version += 1;
        self.cv.notify_all();
    }
}

/// Run `workers` to completion under the seeded schedule; returns the grant
/// trace (worker index per scheduling decision). Worker panics are caught,
/// the remaining schedule drains, and the first payload is re-thrown after
/// every thread has joined — so a failing scenario reports the worker's own
/// assertion, replayable via `seed`.
///
/// Each worker takes an initial gate before its body runs, so the *start*
/// order is scheduled too.
pub fn run(seed: u64, workers: Vec<Worker<'_>>) -> Vec<usize> {
    let n = workers.len();
    let inner = Arc::new(SchedInner {
        state: Mutex::new(SchedState {
            workers: vec![WState::Running; n],
            version: 0,
            abort: false,
        }),
        cv: Condvar::new(),
    });
    let panics: Mutex<Vec<PanicPayload>> = Mutex::new(Vec::new());
    let trace = std::thread::scope(|s| {
        for (i, body) in workers.into_iter().enumerate() {
            let inner = Arc::clone(&inner);
            let panics = &panics;
            s.spawn(move || {
                CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&inner), i)));
                inner.pause(i); // initial gate: start order is scheduled
                let result = catch_unwind(AssertUnwindSafe(body));
                CURRENT.with(|c| *c.borrow_mut() = None);
                inner.finish(i);
                if let Err(payload) = result {
                    lock_recover(panics).push(payload);
                }
            });
        }
        coordinate(&inner, seed)
    });
    let aborted = lock_recover(&inner.state).abort;
    let first = lock_recover(&panics).drain(..).next();
    if let Some(payload) = first {
        std::panic::resume_unwind(payload);
    }
    assert!(
        !aborted,
        "sched: watchdog fired — no scheduler progress for {WATCHDOG:?} \
         (genuine deadlock in the scenario; replay with seed {seed})"
    );
    trace
}

fn coordinate(inner: &SchedInner, seed: u64) -> Vec<usize> {
    let mut r = crate::util::rng(seed);
    let mut trace = Vec::new();
    let mut last_granted: Option<usize> = None;
    // One full quiescence window expired with the last grant still running:
    // overlap the next grant so real-lock blocking cannot stall the world.
    let mut patience = false;
    let mut st = lock_recover(&inner.state);
    let mut last_version = st.version;
    let mut last_progress = Instant::now();
    loop {
        if st.workers.iter().all(|&w| w == WState::Done) {
            return trace;
        }
        if st.version != last_version {
            last_version = st.version;
            last_progress = Instant::now();
        } else if last_progress.elapsed() >= WATCHDOG {
            st.abort = true;
            st.version += 1;
            eprintln!(
                "sched: watchdog (seed {seed}); worker states: {:?}; trace: {trace:?}",
                st.workers
            );
            inner.cv.notify_all();
            return trace;
        }
        let at_yield: Vec<usize> = st
            .workers
            .iter()
            .enumerate()
            .filter(|&(_, &w)| w == WState::AtYield)
            .map(|(i, _)| i)
            .collect();
        let runner_busy = last_granted.is_some_and(|g| st.workers[g] == WState::Running);
        if at_yield.is_empty() || (runner_busy && !patience) {
            let (guard, timeout) = wait_timeout_recover(&inner.cv, st, QUIESCENCE);
            st = guard;
            if timeout.timed_out() && runner_busy {
                patience = true;
            }
            continue;
        }
        let pick = at_yield[r.below(at_yield.len())];
        st.workers[pick] = WState::Running;
        st.version += 1;
        trace.push(pick);
        last_granted = Some(pick);
        patience = false;
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Pure-yield workers replay bit-identically: same seed, same grant
    /// trace, same interleaving-sensitive outcome.
    #[test]
    fn same_seed_same_trace() {
        let scenario = |seed: u64| -> (Vec<usize>, Vec<usize>) {
            let order = Mutex::new(Vec::new());
            let workers: Vec<Worker> = (0..3usize)
                .map(|w| {
                    let order = &order;
                    Box::new(move || {
                        for _ in 0..5 {
                            yield_point();
                            lock_recover(order).push(w);
                        }
                    }) as Worker
                })
                .collect();
            let trace = run(seed, workers);
            (trace, order.into_inner().unwrap())
        };
        let (t1, o1) = scenario(42);
        let (t2, o2) = scenario(42);
        assert_eq!(t1, t2, "same seed must grant identically");
        assert_eq!(o1, o2, "same seed must interleave identically");
        let diverged = (43..48).any(|seed| scenario(seed).0 != t1);
        assert!(diverged, "other seeds must explore different schedules");
    }

    /// A panicking worker surfaces its own payload after every thread
    /// joined, and the rest of the schedule still drains.
    #[test]
    fn worker_panic_is_rethrown_after_join() {
        let progressed = AtomicU64::new(0);
        let err = catch_unwind(AssertUnwindSafe(|| {
            run(
                7,
                vec![
                    Box::new(|| {
                        yield_point();
                        panic!("scenario assertion failed");
                    }) as Worker,
                    Box::new(|| {
                        for _ in 0..3 {
                            yield_point();
                            progressed.fetch_add(1, Ordering::Relaxed);
                        }
                    }) as Worker,
                ],
            );
        }))
        .expect_err("worker panic must propagate");
        let msg = err
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("scenario assertion"), "original payload: {msg}");
        assert_eq!(progressed.load(Ordering::Relaxed), 3, "schedule drained after the panic");
    }

    /// Unregistered threads pass straight through yield points.
    #[test]
    fn yield_point_is_noop_off_schedule() {
        yield_point();
        yield_point();
    }
}
