//! Minimal command-line argument parser (clap replacement for the offline
//! environment): `binary <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments for one invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The first non-flag token, e.g. `serve` in `cosime serve`.
    pub subcommand: Option<String>,
    /// Non-flag tokens after the subcommand, in order.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Sentinel value marking a flag that appeared without a value.
pub const FLAG_SET: &str = "\u{1}"; // sentinel: flag present without value

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]). The first
    /// non-flag token becomes the subcommand; `--key value` and `--key=value`
    /// both work; a `--key` followed by another flag or end-of-args is a
    /// boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let tokens: Vec<String> = raw.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(key) = t.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.flags.insert(key.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.insert(key.to_string(), FLAG_SET.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String value of `--key`, if present with a value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str()).filter(|s| *s != FLAG_SET)
    }

    /// Boolean: present either as a bare `--key` or `--key true`.
    pub fn flag(&self, key: &str) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some(FLAG_SET) => true,
            Some(v) => v != "false" && v != "0",
            None => false,
        }
    }

    /// Typed getters with defaults.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Flag value as u64, or `default` when absent/unparseable.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Flag value as f64, or `default` when absent/unparseable.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Flag value as a string, or `default` when absent.
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("fig6 rows dims");
        assert_eq!(a.subcommand.as_deref(), Some("fig6"));
        assert_eq!(a.positional, vec!["rows", "dims"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse("serve --rows 512 --dims=1024");
        assert_eq!(a.get_usize("rows", 0), 512);
        assert_eq!(a.get_usize("dims", 0), 1024);
    }

    #[test]
    fn bare_flags() {
        let a = parse("fig7 --verbose --part a");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("part"), Some("a"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b 3");
        assert!(a.flag("a"));
        assert_eq!(a.get_u64("b", 0), 3);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
        assert_eq!(a.get_str("missing", "d"), "d");
    }
}
