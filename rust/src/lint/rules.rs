//! The invariant rules enforced by `cosime lint`.
//!
//! Each rule works on the token/comment stream produced by [`super::lexer`];
//! none of them parse Rust properly, and they don't need to — the invariants
//! are local token shapes (`.unwrap(`, `unsafe {`) plus a handful of
//! cross-file set-membership checks. See `DESIGN.md` §Static analysis for the
//! rule catalog and the annotation grammar.
//!
//! ## Escape hatch
//!
//! A violation can be waived in place with
//!
//! ```text
//! // lint: allow(<rule>) -- <reason>
//! ```
//!
//! on the offending line or the line directly above it. The reason is
//! mandatory: a bare `allow` without ` -- ` text does not count, so every
//! waiver in the tree documents *why* the invariant doesn't apply.

use super::lexer::{Lexed, TokKind};
use super::{Finding, Rule};
use crate::util::sync::lock_order;

/// Paths (relative to the repo root, `/`-separated) where the `no-panic`
/// rule applies: the serving stack and the search kernel, where a panic
/// kills a worker thread or a connection instead of returning a wire error.
fn in_no_panic_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/server/")
        || rel.starts_with("rust/src/coordinator/")
        || rel == "rust/src/am/kernel.rs"
        || rel.starts_with("rust/src/am/kernel/")
}

/// Run all single-file rules over one lexed source file.
pub fn lint_file(rel: &str, lexed: &Lexed, out: &mut Vec<Finding>) {
    let allows = AllowSet::parse(lexed);
    let tests = test_spans(lexed);
    safety_comment(rel, lexed, &allows, out);
    if in_no_panic_scope(rel) {
        no_panic(rel, lexed, &allows, &tests, out);
    }
    hot_path_alloc(rel, lexed, &allows, out);
    lock_order_rule(rel, lexed, &allows, &tests, out);
    epoch_discipline(rel, lexed, &allows, &tests, out);
}

// ---------------------------------------------------------------------------
// allow directives

/// Parsed `// lint: allow(<rule>) -- <reason>` directives, keyed by rule
/// name. A directive covers its own line (so it can trail the waived
/// statement) and the next line that carries code, skipping any further
/// comment lines in between (so a multi-line reason still attaches).
struct AllowSet {
    entries: Vec<(String, u32, u32)>,
}

impl AllowSet {
    fn parse(lexed: &Lexed) -> Self {
        let mut entries = Vec::new();
        for c in &lexed.comments {
            let mut rest = c.text.as_str();
            while let Some(pos) = rest.find("lint: allow(") {
                let tail = &rest[pos + "lint: allow(".len()..];
                if let Some(close) = tail.find(')') {
                    let rule = &tail[..close];
                    // The reason after ` -- ` is mandatory.
                    let after = &tail[close + 1..];
                    let reasoned = after
                        .trim_start()
                        .strip_prefix("--")
                        .is_some_and(|r| !r.trim().is_empty());
                    if reasoned {
                        // First code-bearing line after the directive, within
                        // a short window so a stray directive can't waive
                        // code pages away.
                        let target = (c.line + 1..c.line + 8)
                            .find(|&l| lexed.line(l).has_code)
                            .unwrap_or(c.line);
                        entries.push((rule.to_string(), c.line, target));
                    }
                    rest = after;
                } else {
                    break;
                }
            }
        }
        AllowSet { entries }
    }

    /// Is `rule` waived on `line`?
    fn allows(&self, rule: &str, line: u32) -> bool {
        self.entries
            .iter()
            .any(|(r, own, target)| r == rule && (*own == line || *target == line))
    }
}

// ---------------------------------------------------------------------------
// #[cfg(test)] exclusion

/// Token-index ranges covered by `#[cfg(test)]` items (in practice: the
/// `mod tests { … }` blocks). Panicking assertions are idiomatic in tests,
/// so `no-panic` and the wire-exhaustiveness scans skip these spans.
fn test_spans(lexed: &Lexed) -> Vec<(usize, usize)> {
    let t = &lexed.toks;
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 6 < t.len() {
        let is_cfg_test = t[i].is_punct('#')
            && t[i + 1].is_punct('[')
            && t[i + 2].is_ident("cfg")
            && t[i + 3].is_punct('(')
            && t[i + 4].is_ident("test")
            && t[i + 5].is_punct(')')
            && t[i + 6].is_punct(']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // Skip any further attributes, then find the item's body braces
        // (stop at `;` for brace-less items like `#[cfg(test)] use …;`).
        let mut j = i + 7;
        while j + 1 < t.len() && t[j].is_punct('#') && t[j + 1].is_punct('[') {
            let mut depth = 0usize;
            j += 1;
            while j < t.len() {
                if t[j].is_punct('[') {
                    depth += 1;
                } else if t[j].is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        let mut open = None;
        while j < t.len() {
            if t[j].is_punct('{') {
                open = Some(j);
                break;
            }
            if t[j].is_punct(';') {
                break;
            }
            j += 1;
        }
        if let Some(open) = open {
            let mut depth = 0usize;
            let mut k = open;
            while k < t.len() {
                if t[k].is_punct('{') {
                    depth += 1;
                } else if t[k].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            spans.push((i, k));
            i = k + 1;
        } else {
            i = j + 1;
        }
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], idx: usize) -> bool {
    spans.iter().any(|&(a, b)| idx >= a && idx <= b)
}

// ---------------------------------------------------------------------------
// rule: safety-comment

/// Every `unsafe` block, fn, impl, or trait must be immediately preceded by
/// a `// SAFETY:` comment (attribute lines and further comment lines may sit
/// between; a blank or code line breaks the attachment).
fn safety_comment(rel: &str, lexed: &Lexed, allows: &AllowSet, out: &mut Vec<Finding>) {
    let t = &lexed.toks;
    for i in 0..t.len() {
        if !t[i].is_ident("unsafe") {
            continue;
        }
        let what = match t.get(i + 1) {
            Some(n) if n.is_punct('{') => "block",
            Some(n) if n.is_ident("fn") => {
                // `unsafe fn name(` is a declaration; `unsafe fn(` is a
                // function-pointer *type* and needs no SAFETY comment.
                match t.get(i + 2) {
                    Some(m) if m.kind == TokKind::Ident => "fn",
                    _ => continue,
                }
            }
            Some(n) if n.is_ident("impl") => "impl",
            Some(n) if n.is_ident("trait") => "trait",
            Some(n) if n.is_ident("extern") => "extern block",
            _ => continue,
        };
        let line = t[i].line;
        if has_safety_comment(lexed, line) || allows.allows("safety-comment", line) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: Rule::SafetyComment,
            message: format!(
                "`unsafe` {what} without an immediately preceding `// SAFETY:` comment"
            ),
        });
    }
}

fn has_safety_comment(lexed: &Lexed, line: u32) -> bool {
    // A trailing `// SAFETY:` on the same line counts.
    if lexed.comments_on(line).any(|c| c.text.contains("SAFETY:")) {
        return true;
    }
    // Walk upward through comment-only and attribute lines.
    let mut j = line.saturating_sub(1);
    while j >= 1 {
        let info = lexed.line(j);
        if info.has_comment && !info.has_code {
            if lexed.comments_on(j).any(|c| c.text.contains("SAFETY:")) {
                return true;
            }
            j -= 1;
        } else if info.starts_attr {
            j -= 1;
        } else {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// rule: no-panic

const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented", "unreachable"];

/// No `.unwrap()` / `.expect()` / `panic!` / `todo!` / `unimplemented!` /
/// `unreachable!` in serving code paths. Waive deliberate invariants with
/// `// lint: allow(no-panic) -- <reason>`.
fn no_panic(
    rel: &str,
    lexed: &Lexed,
    allows: &AllowSet,
    tests: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let t = &lexed.toks;
    for i in 0..t.len() {
        if in_spans(tests, i) {
            continue;
        }
        let hit = if t[i].is_punct('.')
            && t.get(i + 1).is_some_and(|n| n.is_ident("unwrap") || n.is_ident("expect"))
            && t.get(i + 2).is_some_and(|n| n.is_punct('('))
        {
            Some((t[i + 1].line, format!(".{}()", t[i + 1].text)))
        } else if t[i].kind == TokKind::Ident
            && PANIC_MACROS.contains(&t[i].text.as_str())
            && t.get(i + 1).is_some_and(|n| n.is_punct('!'))
        {
            Some((t[i].line, format!("{}!", t[i].text)))
        } else {
            None
        };
        let Some((line, what)) = hit else { continue };
        if allows.allows("no-panic", line) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: Rule::NoPanic,
            message: format!(
                "`{what}` can panic in a serving code path; return a typed error or add \
                 `// lint: allow(no-panic) -- <reason>`"
            ),
        });
    }
}

// ---------------------------------------------------------------------------
// rule: hot-path-alloc

/// Method calls that allocate (or may reallocate) on common containers.
const ALLOC_METHODS: &[&str] = &[
    "push",
    "to_vec",
    "collect",
    "clone",
    "cloned",
    "to_owned",
    "to_string",
    "extend",
    "extend_from_slice",
];

/// `Type::ctor` pairs that allocate.
const ALLOC_TYPES: &[&str] = &["Vec", "Box", "String", "VecDeque", "HashMap", "BTreeMap"];
const ALLOC_CTORS: &[&str] = &["new", "with_capacity", "from"];

/// Allocating macros.
const ALLOC_MACROS: &[&str] = &["format", "vec"];

/// No allocation inside `// lint: hot-path` … `// lint: end-hot-path`
/// regions. Markers must sit on their own lines; the region covers the
/// lines strictly between them.
fn hot_path_alloc(rel: &str, lexed: &Lexed, allows: &AllowSet, out: &mut Vec<Finding>) {
    // Collect regions from the marker comments.
    let mut regions: Vec<(u32, u32)> = Vec::new();
    let mut open: Option<u32> = None;
    for c in &lexed.comments {
        // A marker is a comment that *is* the directive, not one that merely
        // mentions it — otherwise prose like this rule's own documentation
        // ("allocation inside a `lint: hot-path` region") would open phantom
        // regions. Strip the comment delimiters and require the directive at
        // the start.
        let body = c.text.trim_start_matches(['/', '*', '!']).trim();
        // Check the end marker first: "lint: hot-path" is a prefix of
        // "lint: end-hot-path"'s sibling form.
        if body.starts_with("lint: end-hot-path") {
            match open.take() {
                Some(start) => regions.push((start, c.line)),
                None => out.push(Finding {
                    file: rel.to_string(),
                    line: c.line,
                    rule: Rule::HotPathAlloc,
                    message: "`lint: end-hot-path` without a matching `lint: hot-path`".into(),
                }),
            }
        } else if body.starts_with("lint: hot-path") {
            if let Some(start) = open.replace(c.line) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: start,
                    rule: Rule::HotPathAlloc,
                    message: "`lint: hot-path` region is never closed before the next one".into(),
                });
            }
        }
    }
    if let Some(start) = open {
        out.push(Finding {
            file: rel.to_string(),
            line: start,
            rule: Rule::HotPathAlloc,
            message: "unterminated `lint: hot-path` region (missing `lint: end-hot-path`)".into(),
        });
    }
    if regions.is_empty() {
        return;
    }
    let in_region = |line: u32| regions.iter().any(|&(a, b)| line > a && line < b);

    let t = &lexed.toks;
    for i in 0..t.len() {
        let hit = if t[i].is_punct('.')
            && t.get(i + 1)
                .is_some_and(|n| n.kind == TokKind::Ident && ALLOC_METHODS.contains(&n.text.as_str()))
            && t.get(i + 2).is_some_and(|n| n.is_punct('('))
        {
            Some((t[i + 1].line, format!(".{}()", t[i + 1].text)))
        } else if t[i].kind == TokKind::Ident
            && ALLOC_TYPES.contains(&t[i].text.as_str())
            && t.get(i + 1).is_some_and(|n| n.is_punct(':'))
            && t.get(i + 2).is_some_and(|n| n.is_punct(':'))
            && t.get(i + 3)
                .is_some_and(|n| n.kind == TokKind::Ident && ALLOC_CTORS.contains(&n.text.as_str()))
        {
            Some((t[i].line, format!("{}::{}", t[i].text, t[i + 3].text)))
        } else if t[i].kind == TokKind::Ident
            && ALLOC_MACROS.contains(&t[i].text.as_str())
            && t.get(i + 1).is_some_and(|n| n.is_punct('!'))
        {
            Some((t[i].line, format!("{}!", t[i].text)))
        } else {
            None
        };
        let Some((line, what)) = hit else { continue };
        if !in_region(line) || allows.allows("hot-path-alloc", line) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: Rule::HotPathAlloc,
            message: format!(
                "`{what}` allocates inside a `lint: hot-path` region; hoist it to warm-up \
                 or add `// lint: allow(hot-path-alloc) -- <reason>`"
            ),
        });
    }
}

// ---------------------------------------------------------------------------
// rule: lock-order

/// The tracked-lock acquisition methods: `TrackedMutex::lock`,
/// `TrackedRwLock::read`/`write`.
const ACQUIRE_METHODS: &[&str] = &["lock", "read", "write"];

/// One guard the textual scan currently believes is held.
struct HeldByScan {
    rank: u32,
    class: &'static str,
    field: &'static str,
    /// `let` binding holding the guard, if recognizable — an explicit
    /// `drop(<binding>)` releases it early.
    binding: Option<String>,
    /// Brace depth of the acquiring statement; leaving the enclosing block
    /// releases the guard.
    depth: usize,
    line: u32,
}

/// Static lock-order check, driven by the same declared table the runtime
/// lockdep uses ([`crate::util::sync::lock_order`]): the field names in
/// that table are globally unique, so the identifier left of a
/// `.lock()` / `.read()` / `.write()` call *is* the class key — no type
/// resolution needed. Acquiring a class while a **higher-ranked** class is
/// textually still held (ranks ascend outermost → innermost) inverts the
/// declared order. `let`-bound guards count as held to the end of their
/// enclosing block or an explicit `drop(binding)`; bare acquisitions are
/// treated as instantaneous. Purely textual, so it catches orderings the
/// test suite never executes; the runtime lockdep catches the dynamic
/// ones. Waive deliberate exceptions with
/// `// lint: allow(lock-order) -- <reason>`.
fn lock_order_rule(
    rel: &str,
    lexed: &Lexed,
    allows: &AllowSet,
    tests: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let t = &lexed.toks;
    let mut held: Vec<HeldByScan> = Vec::new();
    let mut depth = 0usize;
    for i in 0..t.len() {
        match t[i].kind {
            TokKind::Punct('{') => depth += 1,
            TokKind::Punct('}') => {
                depth = depth.saturating_sub(1);
                held.retain(|h| h.depth <= depth);
            }
            _ => {}
        }
        // `drop(<binding>)` releases the named guard early.
        if t[i].is_ident("drop")
            && t.get(i + 1).is_some_and(|n| n.is_punct('('))
            && t.get(i + 3).is_some_and(|n| n.is_punct(')'))
        {
            if let Some(name) = t.get(i + 2).filter(|n| n.kind == TokKind::Ident) {
                let pos = held
                    .iter()
                    .rposition(|h| h.binding.as_deref() == Some(name.text.as_str()));
                if let Some(pos) = pos {
                    held.remove(pos);
                }
            }
        }
        // An acquisition: `<field> . lock|read|write (`.
        let is_acquire = i >= 1
            && t[i].is_punct('.')
            && t.get(i + 1).is_some_and(|n| {
                n.kind == TokKind::Ident && ACQUIRE_METHODS.contains(&n.text.as_str())
            })
            && t.get(i + 2).is_some_and(|n| n.is_punct('('))
            && t[i - 1].kind == TokKind::Ident;
        if !is_acquire || in_spans(tests, i) {
            continue;
        }
        let field_tok = &t[i - 1];
        let Some(spec) = lock_order().iter().find(|s| s.field == field_tok.text) else {
            continue;
        };
        let line = t[i + 1].line;
        if let Some(outer) = held.iter().find(|h| h.field != spec.field && spec.rank < h.rank) {
            if !allows.allows("lock-order", line) {
                out.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: Rule::LockOrder,
                    message: format!(
                        "`{}.{}()` acquires lock class \"{}\" (rank {}) while \"{}\" \
                         (rank {}, acquired on line {}) is still held — inverts the \
                         declared order in util::sync::lock_order(); release the outer \
                         guard first or add `// lint: allow(lock-order) -- <reason>`",
                        spec.field,
                        t[i + 1].text,
                        spec.name,
                        spec.rank,
                        outer.class,
                        outer.rank,
                        outer.line
                    ),
                });
            }
        }
        // Held-region bookkeeping: a `let` in the same statement keeps the
        // guard alive past the call; find the statement start and, if it
        // binds a plain identifier, remember it for `drop()` release.
        let mut j = i;
        while j > 0 {
            let k = &t[j - 1].kind;
            if matches!(k, TokKind::Punct(';') | TokKind::Punct('{') | TokKind::Punct('}')) {
                break;
            }
            j -= 1;
        }
        let let_pos = (j..i).find(|&k| t[k].is_ident("let"));
        if let Some(let_pos) = let_pos {
            let mut b = let_pos + 1;
            if t.get(b).is_some_and(|n| n.is_ident("mut")) {
                b += 1;
            }
            let binding = t
                .get(b)
                .filter(|n| n.kind == TokKind::Ident)
                .map(|n| n.text.clone());
            held.push(HeldByScan {
                rank: spec.rank,
                class: spec.name,
                field: spec.field,
                binding,
                depth,
                line,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule: epoch-discipline

/// Every acquisition of the write half of the store's epoch lock (the
/// `tiles.store` class) must sit inside a region opened by a comment
/// starting `lint: epoch-write` and closed by `lint: end-epoch-write`, and
/// each region holding such a write must bump the epoch — a `commit(` or
/// `seed_epoch(` call — before it closes, so a store mutation can never
/// skip the epoch stamp the replication tier depends on. Waive with
/// `// lint: allow(epoch-discipline) -- <reason>`.
fn epoch_discipline(
    rel: &str,
    lexed: &Lexed,
    allows: &AllowSet,
    tests: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let Some(store) = lock_order().iter().find(|s| s.name == "tiles.store") else {
        return;
    };
    // Collect regions from the marker comments (same grammar as hot-path:
    // a marker is a comment that *starts with* the directive, so prose
    // mentioning `lint: epoch-write` mid-sentence opens nothing).
    let mut regions: Vec<(u32, u32)> = Vec::new();
    let mut open: Option<u32> = None;
    for c in &lexed.comments {
        let body = c.text.trim_start_matches(['/', '*', '!']).trim();
        if body.starts_with("lint: end-epoch-write") {
            match open.take() {
                Some(start) => regions.push((start, c.line)),
                None => out.push(Finding {
                    file: rel.to_string(),
                    line: c.line,
                    rule: Rule::EpochDiscipline,
                    message: "`lint: end-epoch-write` without a matching `lint: epoch-write`"
                        .into(),
                }),
            }
        } else if body.starts_with("lint: epoch-write") {
            if let Some(start) = open.replace(c.line) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: start,
                    rule: Rule::EpochDiscipline,
                    message: "`lint: epoch-write` region is never closed before the next one"
                        .into(),
                });
            }
        }
    }
    if let Some(start) = open {
        out.push(Finding {
            file: rel.to_string(),
            line: start,
            rule: Rule::EpochDiscipline,
            message: "unterminated `lint: epoch-write` region (missing `lint: end-epoch-write`)"
                .into(),
        });
    }

    let t = &lexed.toks;
    // Lines that bump the epoch inside this file.
    let bumps: Vec<u32> = (0..t.len())
        .filter(|&i| {
            t[i].kind == TokKind::Ident
                && (t[i].text == "commit" || t[i].text == "seed_epoch")
                && t.get(i + 1).is_some_and(|n| n.is_punct('('))
        })
        .map(|i| t[i].line)
        .collect();
    for i in 1..t.len() {
        let is_store_write = t[i].is_punct('.')
            && t.get(i + 1).is_some_and(|n| n.is_ident("write"))
            && t.get(i + 2).is_some_and(|n| n.is_punct('('))
            && t[i - 1].is_ident(store.field);
        if !is_store_write || in_spans(tests, i) {
            continue;
        }
        let line = t[i + 1].line;
        if allows.allows("epoch-discipline", line) {
            continue;
        }
        match regions.iter().find(|&&(a, b)| line > a && line < b) {
            None => out.push(Finding {
                file: rel.to_string(),
                line,
                rule: Rule::EpochDiscipline,
                message: format!(
                    "`{}.write()` takes the write half of the epoch lock outside a \
                     `lint: epoch-write` region; wrap the mutation or add \
                     `// lint: allow(epoch-discipline) -- <reason>`",
                    store.field
                ),
            }),
            Some(&(a, b)) => {
                if !bumps.iter().any(|&l| l > a && l < b) {
                    out.push(Finding {
                        file: rel.to_string(),
                        line,
                        rule: Rule::EpochDiscipline,
                        message: format!(
                            "the `lint: epoch-write` region starting on line {a} never \
                             bumps the epoch (no `commit(`/`seed_epoch(` before line {b})"
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: wire-exhaustive

/// Variant names (and decl lines) of `enum <name>` in a lexed file.
fn enum_variants(lexed: &Lexed, name: &str) -> Vec<(String, u32)> {
    let t = &lexed.toks;
    let mut i = 0usize;
    while i + 1 < t.len() {
        if !(t[i].is_ident("enum") && t[i + 1].is_ident(name)) {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        while j < t.len() && !t[j].is_punct('{') {
            j += 1;
        }
        let mut vars = Vec::new();
        let mut depth = 0usize; // braces nested inside the enum body
        let mut pd = 0usize; // parens (tuple variants)
        let mut bd = 0usize; // brackets (attributes)
        let mut prev: Option<char> = Some('{');
        let mut k = j + 1;
        while k < t.len() {
            let tok = &t[k];
            match tok.kind {
                TokKind::Punct('{') => depth += 1,
                TokKind::Punct('}') => {
                    if depth == 0 {
                        break;
                    }
                    depth -= 1;
                }
                TokKind::Punct('(') => pd += 1,
                TokKind::Punct(')') => pd = pd.saturating_sub(1),
                TokKind::Punct('[') => bd += 1,
                TokKind::Punct(']') => bd = bd.saturating_sub(1),
                _ => {}
            }
            if depth == 0
                && pd == 0
                && bd == 0
                && tok.kind == TokKind::Ident
                && matches!(prev, Some('{') | Some(',') | Some(']') | Some('}'))
            {
                vars.push((tok.text.clone(), tok.line));
            }
            prev = match tok.kind {
                TokKind::Punct(c) => Some(c),
                _ => None,
            };
            k += 1;
        }
        return vars;
    }
    Vec::new()
}

/// Does any file contain the path reference `ty::variant` outside its
/// `#[cfg(test)]` spans?
fn any_path_ref(files: &[(&Lexed, &[(usize, usize)])], ty: &str, variant: &str) -> bool {
    for (lexed, tests) in files {
        let t = &lexed.toks;
        for i in 0..t.len().saturating_sub(3) {
            if t[i].is_ident(ty)
                && t[i + 1].is_punct(':')
                && t[i + 2].is_punct(':')
                && t[i + 3].is_ident(variant)
                && !in_spans(tests, i)
            {
                return true;
            }
        }
    }
    false
}

/// Cross-file exhaustiveness over the wire enums: every `Op` variant must
/// be dispatched somewhere in `tcp.rs` / `eventloop.rs` / `client.rs`, and
/// every `ErrorCode` variant must be produced or translated somewhere in the
/// serving layer (including `protocol.rs`'s own conversion impls — the enum
/// declaration itself doesn't count because variant uses inside the decl are
/// unqualified). Test-only references don't count.
pub fn wire_exhaustive(
    protocol: (&str, &Lexed),
    serving: &[(&str, &Lexed)],
    out: &mut Vec<Finding>,
) {
    let (proto_rel, proto) = protocol;
    let proto_tests = test_spans(proto);
    let serving_lex: Vec<(&Lexed, Vec<(usize, usize)>)> = serving
        .iter()
        .map(|(_, l)| (*l, test_spans(l)))
        .collect();
    let dispatch: Vec<(&Lexed, &[(usize, usize)])> = serving_lex
        .iter()
        .map(|(l, s)| (*l, s.as_slice()))
        .collect();
    let mut with_proto: Vec<(&Lexed, &[(usize, usize)])> = dispatch.clone();
    with_proto.push((proto, proto_tests.as_slice()));

    for (variant, line) in enum_variants(proto, "Op") {
        if !any_path_ref(&dispatch, "Op", &variant) {
            out.push(Finding {
                file: proto_rel.to_string(),
                line,
                rule: Rule::WireExhaustive,
                message: format!(
                    "opcode `Op::{variant}` is declared but never dispatched in \
                     tcp.rs / eventloop.rs / client.rs"
                ),
            });
        }
    }
    for (variant, line) in enum_variants(proto, "ErrorCode") {
        if !any_path_ref(&with_proto, "ErrorCode", &variant) {
            out.push(Finding {
                file: proto_rel.to_string(),
                line,
                rule: Rule::WireExhaustive,
                message: format!(
                    "`ErrorCode::{variant}` is declared but never produced or translated \
                     in the serving layer"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule: config-doc

/// Config keys parsed by `config/mod.rs`: identifiers listed inside the
/// `bind_toml!` bracket groups plus bare string-literal match-arm patterns
/// (`"listen" => …`), which cover both hand-written `FromToml` impls and the
/// `[section]` dispatch.
fn config_keys(lexed: &Lexed) -> Vec<(String, u32)> {
    let t = &lexed.toks;
    let mut keys: Vec<(String, u32)> = Vec::new();
    let mut push = |name: &str, line: u32, keys: &mut Vec<(String, u32)>| {
        if !name.is_empty() && !keys.iter().any(|(k, _)| k == name) {
            keys.push((name.to_string(), line));
        }
    };

    // bind_toml! invocations: idents inside [ … ] groups are field names,
    // which double as the TOML key names.
    let mut i = 0usize;
    while i + 1 < t.len() {
        if t[i].is_ident("bind_toml") && t[i + 1].is_punct('!') {
            let mut j = i + 2;
            // Find the macro's opening delimiter and walk to its close.
            let (open, close) = match t.get(j).map(|x| x.kind) {
                Some(TokKind::Punct('(')) => ('(', ')'),
                Some(TokKind::Punct('{')) => ('{', '}'),
                Some(TokKind::Punct('[')) => ('[', ']'),
                _ => {
                    i += 1;
                    continue;
                }
            };
            let mut depth = 0usize;
            let mut bracket = 0usize;
            while j < t.len() {
                if t[j].is_punct(open) {
                    depth += 1;
                } else if t[j].is_punct(close) {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t[j].is_punct('[') {
                    bracket += 1;
                } else if t[j].is_punct(']') {
                    bracket = bracket.saturating_sub(1);
                } else if bracket > 0 && t[j].kind == TokKind::Ident {
                    push(&t[j].text, t[j].line, &mut keys);
                }
                j += 1;
            }
            i = j;
        }
        i += 1;
    }

    // Bare string-literal match arms: `"key" => …`.
    for i in 0..t.len().saturating_sub(2) {
        if t[i].kind == TokKind::Str
            && t[i + 1].is_punct('=')
            && t[i + 2].is_punct('>')
        {
            let raw = t[i].text.trim_matches('"');
            push(raw, t[i].line, &mut keys);
        }
    }
    keys
}

/// Every config key parsed in `config/` must appear in the rust/README.md
/// configuration reference — backticked (`` `key` ``), as a section header
/// (`[key]`), or quoted inside a TOML example (`"key"`).
pub fn config_doc(config: (&str, &Lexed), readme: &str, out: &mut Vec<Finding>) {
    let (rel, lexed) = config;
    for (key, line) in config_keys(lexed) {
        let documented = readme.contains(&format!("`{key}`"))
            || readme.contains(&format!("[{key}]"))
            || readme.contains(&format!("\"{key}\""))
            || readme.contains(&format!("`{key} "));
        if !documented {
            out.push(Finding {
                file: rel.to_string(),
                line,
                rule: Rule::ConfigDoc,
                message: format!(
                    "config key `{key}` is parsed here but not documented in \
                     rust/README.md's configuration reference"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let mut out = Vec::new();
        lint_file(rel, &lex(src), &mut out);
        out
    }

    #[test]
    fn unsafe_without_safety_fires() {
        let out = findings("rust/src/x.rs", "fn f() {\n    unsafe { core::hint::unreachable_unchecked() }\n}\n");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2);
        assert_eq!(out[0].rule, Rule::SafetyComment);
    }

    #[test]
    fn unsafe_with_safety_is_clean() {
        let src = "fn f() {\n    // SAFETY: caller checked the bounds.\n    unsafe { op() }\n}\n";
        assert!(findings("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn attrs_between_safety_and_unsafe_are_fine() {
        let src = "// SAFETY: target_feature matches runtime dispatch.\n#[target_feature(enable = \"avx2\")]\nunsafe fn kernel() {}\n";
        assert!(findings("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn fn_pointer_types_are_not_flagged() {
        let src = "struct K { f: unsafe fn(&[u64], &[u64]) -> u64 }\n";
        assert!(findings("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn unwrap_flagged_only_in_scope() {
        let src = "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n";
        assert_eq!(findings("rust/src/server/x.rs", src).len(), 1);
        assert_eq!(findings("rust/src/coordinator/x.rs", src).len(), 1);
        assert!(findings("rust/src/device/x.rs", src).is_empty());
        assert!(findings("rust/benches/x.rs", src).is_empty());
    }

    #[test]
    fn allow_directive_waives_with_reason_only() {
        let with_reason =
            "fn f(v: Option<u32>) -> u32 {\n    // lint: allow(no-panic) -- checked above\n    v.unwrap()\n}\n";
        assert!(findings("rust/src/server/x.rs", with_reason).is_empty());
        let no_reason =
            "fn f(v: Option<u32>) -> u32 {\n    // lint: allow(no-panic)\n    v.unwrap()\n}\n";
        assert_eq!(findings("rust/src/server/x.rs", no_reason).len(), 1);
    }

    #[test]
    fn test_mods_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); panic!(\"x\"); }\n}\n";
        assert!(findings("rust/src/server/x.rs", src).is_empty());
    }

    #[test]
    fn panic_macros_fire() {
        let src = "fn f() { todo!() }\n";
        let out = findings("rust/src/coordinator/x.rs", src);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("todo!"));
    }

    #[test]
    fn unwrap_in_comment_or_string_is_ignored() {
        let src = "// calls unwrap() internally\nfn f() { let s = \".unwrap()\"; let _ = s; }\n";
        assert!(findings("rust/src/server/x.rs", src).is_empty());
    }

    #[test]
    fn hot_path_alloc_fires_inside_region() {
        let src = "fn f(v: &mut Vec<u32>) {\n    // lint: hot-path\n    v.push(1);\n    // lint: end-hot-path\n    v.push(2);\n}\n";
        let out = findings("rust/src/x.rs", src);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 3);
        assert_eq!(out[0].rule, Rule::HotPathAlloc);
    }

    #[test]
    fn hot_path_region_must_terminate() {
        let src = "fn f() {\n    // lint: hot-path\n}\n";
        let out = findings("rust/src/x.rs", src);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("unterminated"));
    }

    #[test]
    fn hot_path_ctor_and_macro_forms() {
        let src = "fn f() {\n    // lint: hot-path\n    let v: Vec<u32> = Vec::new();\n    let s = format!(\"x\");\n    // lint: end-hot-path\n    let _ = (v, s);\n}\n";
        let out = findings("rust/src/x.rs", src);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn enum_variants_parse_payloads_and_discriminants() {
        let l = lex(
            "pub enum E {\n    A = 0x01,\n    B(String),\n    #[allow(dead_code)]\n    C { x: u64, y: u64 },\n    D,\n}\n",
        );
        let vars: Vec<String> = enum_variants(&l, "E").into_iter().map(|(n, _)| n).collect();
        assert_eq!(vars, ["A", "B", "C", "D"]);
    }

    #[test]
    fn wire_exhaustive_finds_undisipatched_op() {
        let proto = lex("pub enum Op { Search = 0x01, Ghost = 0x7F }\npub enum ErrorCode { Busy = 1 }\nimpl ErrorCode { fn c(&self) { let _ = ErrorCode::Busy; } }\n");
        let tcp = lex("fn d(op: Op) { match op { Op::Search => {}, _ => {} } }\n");
        let mut out = Vec::new();
        wire_exhaustive(("p.rs", &proto), &[("tcp.rs", &tcp)], &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("Op::Ghost"));
    }

    #[test]
    fn lock_order_inversion_fires() {
        let src = "fn f(s: &S) {\n    let g = s.counters.lock();\n    let w = s.writer.lock();\n    drop(w);\n    drop(g);\n}\n";
        let out = findings("rust/src/x.rs", src);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, Rule::LockOrder);
        assert_eq!(out[0].line, 3);
        assert!(out[0].message.contains("metrics.counters"), "{}", out[0].message);
        assert!(out[0].message.contains("service.writer"), "{}", out[0].message);
    }

    #[test]
    fn lock_order_ascending_dropped_scoped_and_untracked_are_clean() {
        let ascending = "fn f(s: &S) {\n    let w = s.writer.lock();\n    let g = s.counters.lock();\n}\n";
        assert!(findings("rust/src/x.rs", ascending).is_empty());
        let dropped = "fn f(s: &S) {\n    let g = s.counters.lock();\n    drop(g);\n    let w = s.writer.lock();\n}\n";
        assert!(findings("rust/src/x.rs", dropped).is_empty());
        let scoped = "fn f(s: &S) {\n    {\n        let g = s.counters.lock();\n    }\n    let w = s.writer.lock();\n}\n";
        assert!(findings("rust/src/x.rs", scoped).is_empty());
        let untracked = "fn f(s: &S) {\n    let g = s.mystery.lock();\n    let w = s.writer.lock();\n}\n";
        assert!(findings("rust/src/x.rs", untracked).is_empty());
    }

    #[test]
    fn lock_order_same_class_and_waiver_are_clean() {
        let same = "fn f(s: &S) {\n    let a = s.conn.lock();\n    let b = s.conn.lock();\n}\n";
        assert!(findings("rust/src/x.rs", same).is_empty());
        let waived = "fn f(s: &S) {\n    let g = s.counters.lock();\n    // lint: allow(lock-order) -- shutdown path; outer guard is idle\n    let w = s.writer.lock();\n}\n";
        assert!(findings("rust/src/x.rs", waived).is_empty());
    }

    #[test]
    fn epoch_write_outside_region_fires() {
        let src = "fn f(s: &S) {\n    let mut set = s.tiles.write();\n    set.rows += 1;\n}\n";
        let out = findings("rust/src/x.rs", src);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, Rule::EpochDiscipline);
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn epoch_write_region_must_bump_the_epoch() {
        let committed = "fn f(s: &S) {\n    // lint: epoch-write -- fixture\n    let mut set = s.tiles.write();\n    let c = s.commit(&set);\n    // lint: end-epoch-write\n    let _ = c;\n}\n";
        assert!(findings("rust/src/x.rs", committed).is_empty());
        let seeded = "fn f(s: &S) {\n    // lint: epoch-write -- fixture\n    let mut set = s.tiles.write();\n    s.seed_epoch(7);\n    // lint: end-epoch-write\n}\n";
        assert!(findings("rust/src/x.rs", seeded).is_empty());
        let no_bump = "fn f(s: &S) {\n    // lint: epoch-write -- fixture\n    let mut set = s.tiles.write();\n    set.rows += 1;\n    // lint: end-epoch-write\n}\n";
        let out = findings("rust/src/x.rs", no_bump);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("bumps the epoch"), "{}", out[0].message);
    }

    #[test]
    fn epoch_write_markers_must_pair_and_waiver_applies() {
        let unterminated = "fn f() {\n    // lint: epoch-write -- fixture\n}\n";
        let out = findings("rust/src/x.rs", unterminated);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("unterminated"), "{}", out[0].message);
        let orphan = "fn f() {\n    // lint: end-epoch-write\n}\n";
        let out = findings("rust/src/x.rs", orphan);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("without a matching"), "{}", out[0].message);
        let waived = "fn f(s: &S) {\n    // lint: allow(epoch-discipline) -- bulk loader stamps the epoch itself\n    let mut set = s.tiles.write();\n}\n";
        assert!(findings("rust/src/x.rs", waived).is_empty());
    }

    #[test]
    fn config_doc_flags_undocumented_key() {
        let cfg = lex("impl FromToml for C {\n    fn set(&mut self, key: &str) {\n        match key {\n            \"listen\" => {}\n            \"mystery_knob\" => {}\n            _ => {}\n        }\n    }\n}\n");
        let mut out = Vec::new();
        config_doc(("c.rs", &cfg), "docs: `listen` is the bind address", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("mystery_knob"));
        assert_eq!(out[0].line, 5);
    }
}
