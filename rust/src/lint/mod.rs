//! `cosime lint` — the in-crate invariant linter.
//!
//! A self-contained static-analysis pass (no `syn`, no external tooling)
//! that walks `rust/src`, `rust/benches`, `rust/tests`, and `examples/` and
//! enforces the project invariants the compiler can't:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `safety-comment`  | every `unsafe` block/fn/impl is immediately preceded by `// SAFETY:` |
//! | `no-panic`        | no `unwrap`/`expect`/`panic!`/`todo!`/`unimplemented!`/`unreachable!` in server, coordinator, or kernel code paths |
//! | `hot-path-alloc`  | no allocation inside `// lint: hot-path` … `// lint: end-hot-path` regions |
//! | `wire-exhaustive` | every `Op`/`ErrorCode` variant in `server/protocol.rs` is dispatched/produced in the serving layer |
//! | `config-doc`      | every config key parsed in `config/` is documented in rust/README.md |
//! | `lock-order`      | no tracked-class acquisition while a higher-ranked class is textually held (the table in `util::sync::lock_order`) |
//! | `epoch-discipline`| every write-half acquisition of the store's epoch lock sits in a `// lint: epoch-write` region that bumps the epoch |
//!
//! Violations can be waived in place with
//! `// lint: allow(<rule>) -- <reason>` (the reason is mandatory).
//!
//! The pass runs three ways, all through [`lint_tree`]:
//!
//! * `cosime lint [--json]` — CLI entry, non-zero exit on findings,
//! * `cargo test` — `rust/tests/lint.rs` is a tier-1 gate,
//! * CI — the `lint-invariants` job.

/// Hand-rolled token-level Rust lexer (comments, strings, line shapes).
pub mod lexer;
/// The individual lint rules and their token-sequence matchers.
pub mod rules;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Which invariant a [`Finding`] violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// `unsafe` without an immediately preceding `// SAFETY:` comment.
    SafetyComment,
    /// Panicking call/macro in a serving code path.
    NoPanic,
    /// Allocation inside a `// lint: hot-path` region.
    HotPathAlloc,
    /// Wire enum variant never dispatched in the serving layer.
    WireExhaustive,
    /// Config key parsed but undocumented in rust/README.md.
    ConfigDoc,
    /// Tracked lock acquired while a higher-ranked class is held.
    LockOrder,
    /// Store epoch-lock write outside a committed `epoch-write` region.
    EpochDiscipline,
}

impl Rule {
    /// The rule's stable name, as used in `lint: allow(<name>)` directives
    /// and in output.
    pub fn name(self) -> &'static str {
        match self {
            Rule::SafetyComment => "safety-comment",
            Rule::NoPanic => "no-panic",
            Rule::HotPathAlloc => "hot-path-alloc",
            Rule::WireExhaustive => "wire-exhaustive",
            Rule::ConfigDoc => "config-doc",
            Rule::LockOrder => "lock-order",
            Rule::EpochDiscipline => "epoch-discipline",
        }
    }
}

/// One lint violation: `file:line: rule: message`.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the repo root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: u32,
    /// Which rule fired.
    pub rule: Rule,
    /// Human-readable description, including the fix or waiver syntax.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}",
            self.file,
            self.line,
            self.rule.name(),
            self.message
        )
    }
}

/// Locate the repo root (the directory containing `rust/src/lib.rs`) by
/// walking up from `start`. Returns `None` if no ancestor qualifies.
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    for _ in 0..6 {
        if dir.join("rust/src/lib.rs").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            break;
        }
    }
    None
}

/// Locate the repo root from the current working directory (works both from
/// the repo root and from `rust/`, where `cargo test` runs).
pub fn repo_root() -> Option<PathBuf> {
    find_repo_root(&std::env::current_dir().ok()?)
}

/// The directories (relative to the repo root) the linter walks.
const WALK_ROOTS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];

/// Recursively collect `.rs` files under `dir`, appending repo-relative
/// `/`-separated paths to `out`. Deterministic: entries are sorted.
fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Run the single-file rules over one source string. This is the entry the
/// self-tests use for fixture snippets; `rel` decides rule scoping exactly
/// as it does for on-disk files.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    rules::lint_file(rel, &lexer::lex(src), &mut out);
    out
}

/// Lint the whole tree rooted at `root` (the repo root). Returns all
/// findings, sorted by file then line.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    for walk in WALK_ROOTS {
        let dir = root.join(walk);
        if dir.is_dir() {
            collect_rs(root, &dir, &mut files)?;
        }
    }

    let mut findings = Vec::new();
    let mut lexed_cache: Vec<(String, lexer::Lexed)> = Vec::with_capacity(files.len());
    for rel in &files {
        let src = fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading {rel}"))?;
        let lexed = lexer::lex(&src);
        rules::lint_file(rel, &lexed, &mut findings);
        lexed_cache.push((rel.clone(), lexed));
    }

    // Cross-file rules.
    let get = |name: &str| {
        lexed_cache
            .iter()
            .find(|(rel, _)| rel == name)
            .map(|(rel, lexed)| (rel.as_str(), lexed))
    };
    if let Some(protocol) = get("rust/src/server/protocol.rs") {
        let serving: Vec<(&str, &lexer::Lexed)> = [
            "rust/src/server/tcp.rs",
            "rust/src/server/eventloop.rs",
            "rust/src/server/client.rs",
            "rust/src/server/remote.rs",
        ]
        .iter()
        .filter_map(|n| get(n))
        .collect();
        rules::wire_exhaustive(protocol, &serving, &mut findings);
    }
    if let Some(config) = get("rust/src/config/mod.rs") {
        let readme = fs::read_to_string(root.join("rust/README.md")).unwrap_or_default();
        rules::config_doc(config, &readme, &mut findings);
    }

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(findings)
}

/// One `// lint: allow(<rule>) -- <reason>` waiver somewhere in the tree.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Path relative to the repo root, `/`-separated.
    pub file: String,
    /// 1-based line of the directive comment.
    pub line: u32,
    /// Rule name being waived.
    pub rule: String,
    /// The mandatory reason text after ` -- `.
    pub reason: String,
    /// Abbreviated commit that introduced the directive line (`git blame`);
    /// `"uncommitted"` for working-tree edits, `"unknown"` when blame is
    /// unavailable (no git binary, tarball checkout).
    pub commit: String,
}

/// `git blame` one line, returning the abbreviated introducing commit.
fn blame_line(root: &Path, rel: &str, line: u32) -> String {
    let out = std::process::Command::new("git")
        .arg("-C")
        .arg(root)
        .arg("blame")
        .arg("-L")
        .arg(format!("{line},{line}"))
        .arg("--porcelain")
        .arg("--")
        .arg(rel)
        .output();
    match out {
        Ok(o) if o.status.success() => {
            let text = String::from_utf8_lossy(&o.stdout);
            let hash = text.split_whitespace().next().unwrap_or("");
            if hash.is_empty() {
                "unknown".into()
            } else if hash.chars().all(|c| c == '0') {
                "uncommitted".into()
            } else {
                hash.chars().take(8).collect()
            }
        }
        _ => "unknown".into(),
    }
}

/// Collect every waiver in the tree, annotated with the introducing commit.
/// Sorted by file then line — this is the `cosime lint --waivers` audit
/// report, so reviewers see each escape hatch, its documented reason, and
/// when it entered the tree in one place.
pub fn waiver_report(root: &Path) -> Result<Vec<Waiver>> {
    let mut files = Vec::new();
    for walk in WALK_ROOTS {
        let dir = root.join(walk);
        if dir.is_dir() {
            collect_rs(root, &dir, &mut files)?;
        }
    }
    let mut out = Vec::new();
    for rel in &files {
        let src = fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading {rel}"))?;
        for c in &lexer::lex(&src).comments {
            let mut rest = c.text.as_str();
            while let Some(pos) = rest.find("lint: allow(") {
                let tail = &rest[pos + "lint: allow(".len()..];
                let Some(close) = tail.find(')') else { break };
                let rule = tail[..close].to_string();
                let after = &tail[close + 1..];
                if let Some(reason) = after.trim_start().strip_prefix("--") {
                    let reason = reason.trim();
                    if !reason.is_empty() {
                        out.push(Waiver {
                            file: rel.clone(),
                            line: c.line,
                            rule,
                            reason: reason.to_string(),
                            commit: blame_line(root, rel, c.line),
                        });
                    }
                }
                rest = after;
            }
        }
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(out)
}

/// Render the waiver report as human-readable text, one waiver per line.
pub fn render_waivers_text(waivers: &[Waiver]) -> String {
    let mut s = String::new();
    for w in waivers {
        s.push_str(&format!(
            "{}:{}: {} [{}] -- {}\n",
            w.file, w.line, w.rule, w.commit, w.reason
        ));
    }
    s.push_str(&format!("{} waiver(s)\n", waivers.len()));
    s
}

/// Render the waiver report as JSON (`--waivers --json`, the CI artifact).
pub fn render_waivers_json(waivers: &[Waiver]) -> String {
    let items = waivers.iter().map(|w| {
        Json::obj(vec![
            ("file", Json::str(&w.file)),
            ("line", Json::num(w.line as f64)),
            ("rule", Json::str(&w.rule)),
            ("reason", Json::str(&w.reason)),
            ("commit", Json::str(&w.commit)),
        ])
    });
    Json::obj(vec![
        ("count", Json::num(waivers.len() as f64)),
        ("waivers", Json::arr(items)),
    ])
    .to_string_pretty()
}

/// Render findings as a JSON document (the `--json` mode):
/// `{"findings": [{"file", "line", "rule", "message"}, …], "count": N}`.
pub fn render_json(findings: &[Finding]) -> String {
    let items = findings.iter().map(|f| {
        Json::obj(vec![
            ("file", Json::str(&f.file)),
            ("line", Json::num(f.line as f64)),
            ("rule", Json::str(f.rule.name())),
            ("message", Json::str(&f.message)),
        ])
    });
    Json::obj(vec![
        ("count", Json::num(findings.len() as f64)),
        ("findings", Json::arr(items)),
    ])
    .to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_file_line_rule_message() {
        let f = Finding {
            file: "rust/src/x.rs".into(),
            line: 7,
            rule: Rule::NoPanic,
            message: "boom".into(),
        };
        assert_eq!(f.to_string(), "rust/src/x.rs:7: no-panic: boom");
    }

    #[test]
    fn json_round_trips_through_util_json() {
        let f = vec![Finding {
            file: "a.rs".into(),
            line: 1,
            rule: Rule::SafetyComment,
            message: "m".into(),
        }];
        let parsed = Json::parse(&render_json(&f)).expect("valid json");
        assert_eq!(parsed.get("count").and_then(Json::as_usize), Some(1));
        let arr = parsed.get("findings").and_then(Json::as_arr).expect("arr");
        assert_eq!(arr[0].get("rule").and_then(Json::as_str), Some("safety-comment"));
    }

    #[test]
    fn waiver_report_lists_known_waivers_with_reasons() {
        let root = repo_root().expect("repo root");
        let ws = waiver_report(&root).expect("report");
        assert!(!ws.is_empty(), "the tree carries documented waivers");
        assert!(ws.iter().all(|w| !w.reason.is_empty() && !w.commit.is_empty()));
        assert!(ws.iter().any(|w| w.rule == "no-panic"));
        let json = Json::parse(&render_waivers_json(&ws)).expect("valid json");
        assert_eq!(json.get("count").and_then(Json::as_usize), Some(ws.len()));
        let text = render_waivers_text(&ws);
        assert!(text.contains("waiver(s)"));
    }

    #[test]
    fn repo_root_is_found_from_rust_dir() {
        // Tests run with cwd == rust/; the root must still resolve.
        let root = repo_root().expect("repo root");
        assert!(root.join("rust/src/lint/mod.rs").is_file());
    }
}
