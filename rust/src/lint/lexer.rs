//! A minimal, dependency-free Rust lexer for the invariant linter.
//!
//! This is **not** a full Rust front end — it splits source text into just
//! enough structure for token-sequence rules: identifiers, punctuation,
//! string/char literals, numbers, and comments, each tagged with the 1-based
//! line it starts on. Getting comments and literals right is the whole point:
//! a substring scan would flag `unwrap` inside a doc comment or a string, and
//! would miss that `r#"…"#` can contain anything at all. The lexer handles
//! line comments, nested block comments, raw/byte/raw-byte strings, and the
//! char-literal vs. lifetime ambiguity (`'a'` vs. `'a`), which is the only
//! genuinely fiddly part of tokenizing Rust without a parser.
//!
//! The rules in [`super::rules`] consume the output three ways:
//!
//! * token-sequence matching (e.g. `.` `unwrap` `(`) for call-site rules,
//! * the comment list for `// SAFETY:` and `// lint:` directives,
//! * per-line shape info (code / attribute / comment-only / blank) for the
//!   "immediately preceded by" attachment walk.

/// What kind of token a [`Tok`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `unwrap`, `Vec`, …).
    Ident,
    /// Single punctuation character (`.`, `(`, `{`, `!`, `#`, …).
    Punct(char),
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Character or byte literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// Numeric literal, including suffixes and hex (`1_000`, `0x1F`, `2.5e3`).
    Num,
}

/// One lexed token with its source position.
#[derive(Debug, Clone)]
pub struct Tok {
    /// 1-based line the token starts on.
    pub line: u32,
    /// Token classification.
    pub kind: TokKind,
    /// Verbatim source text of the token (string literals keep their quotes).
    pub text: String,
}

impl Tok {
    /// True if this token is the identifier `name`.
    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text == name
    }

    /// True if this token is the punctuation character `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }
}

/// One comment (line or block) with the line it starts on.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: u32,
    /// Verbatim comment text including the `//` / `/* */` delimiters.
    pub text: String,
}

/// Per-line shape classification, used by the SAFETY attachment walk.
#[derive(Debug, Clone, Copy, Default)]
pub struct LineInfo {
    /// A non-comment token starts on this line.
    pub has_code: bool,
    /// A comment starts on this line.
    pub has_comment: bool,
    /// The first token on this line is `#` (an attribute line).
    pub starts_attr: bool,
}

/// The full lexer output for one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    /// All non-comment tokens in source order.
    pub toks: Vec<Tok>,
    /// All comments in source order.
    pub comments: Vec<Comment>,
    /// `lines[n]` describes line `n` (index 0 is unused padding).
    pub lines: Vec<LineInfo>,
}

impl Lexed {
    /// Shape info for 1-based line `n` (default/blank if out of range).
    pub fn line(&self, n: u32) -> LineInfo {
        self.lines.get(n as usize).copied().unwrap_or_default()
    }

    /// Iterator over comments that start on 1-based line `n`.
    pub fn comments_on(&self, n: u32) -> impl Iterator<Item = &Comment> {
        self.comments.iter().filter(move |c| c.line == n)
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte length of the UTF-8 character starting with `b` (1 for malformed
/// input, which keeps the lexer moving on garbage bytes).
fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Tokenize one Rust source file.
///
/// Never fails: malformed input (unterminated strings, stray bytes) degrades
/// to best-effort tokens rather than an error, because the linter must keep
/// walking the rest of the tree even if one file is mid-edit.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut line: u32 = 1;
    let mut i = 0usize;

    // Track which line each emitted item starts on so per-line shape info can
    // be filled in as we go.
    fn mark(lines: &mut Vec<LineInfo>, line: u32) -> &mut LineInfo {
        let idx = line as usize;
        if lines.len() <= idx {
            lines.resize(idx + 1, LineInfo::default());
        }
        &mut lines[idx]
    }

    while i < n {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            // Line comment (also `///` and `//!` doc comments).
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let start = i;
                let l0 = line;
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
                mark(&mut out.lines, l0).has_comment = true;
                out.comments.push(Comment {
                    line: l0,
                    text: src[start..i].to_string(),
                });
            }
            // Block comment; Rust block comments nest.
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let start = i;
                let l0 = line;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                mark(&mut out.lines, l0).has_comment = true;
                out.comments.push(Comment {
                    line: l0,
                    text: src[start..i].to_string(),
                });
            }
            // Plain string literal.
            b'"' => {
                let l0 = line;
                let start = i;
                i = scan_string(b, i, &mut line);
                push_tok(&mut out, l0, TokKind::Str, &src[start..i]);
            }
            // Char literal or lifetime.
            b'\'' => {
                let l0 = line;
                let start = i;
                let (end, kind) = scan_char_or_lifetime(b, i);
                i = end;
                push_tok(&mut out, l0, kind, &src[start..i]);
            }
            // `r"…"`, `r#"…"#`, `r#ident`, or a plain ident starting with r.
            b'r' => {
                let l0 = line;
                let start = i;
                if let Some(end) = try_scan_raw_string(b, i, &mut line) {
                    i = end;
                    push_tok(&mut out, l0, TokKind::Str, &src[start..i]);
                } else if i + 1 < n && b[i + 1] == b'#' && i + 2 < n && is_ident_start(b[i + 2]) {
                    // Raw identifier `r#type`.
                    i += 2;
                    while i < n && is_ident_cont(b[i]) {
                        i += 1;
                    }
                    push_tok(&mut out, l0, TokKind::Ident, &src[start..i]);
                } else {
                    i = scan_ident(b, i);
                    push_tok(&mut out, l0, TokKind::Ident, &src[start..i]);
                }
            }
            // `b"…"`, `b'…'`, `br"…"`, or a plain ident starting with b.
            b'b' => {
                let l0 = line;
                let start = i;
                if i + 1 < n && b[i + 1] == b'"' {
                    i = scan_string(b, i + 1, &mut line);
                    push_tok(&mut out, l0, TokKind::Str, &src[start..i]);
                } else if i + 1 < n && b[i + 1] == b'\'' {
                    let (end, _) = scan_char_or_lifetime(b, i + 1);
                    i = end;
                    push_tok(&mut out, l0, TokKind::Char, &src[start..i]);
                } else if i + 1 < n && b[i + 1] == b'r' {
                    if let Some(end) = try_scan_raw_string(b, i + 1, &mut line) {
                        i = end;
                        push_tok(&mut out, l0, TokKind::Str, &src[start..i]);
                    } else {
                        i = scan_ident(b, i);
                        push_tok(&mut out, l0, TokKind::Ident, &src[start..i]);
                    }
                } else {
                    i = scan_ident(b, i);
                    push_tok(&mut out, l0, TokKind::Ident, &src[start..i]);
                }
            }
            c if is_ident_start(c) => {
                let l0 = line;
                let start = i;
                i = scan_ident(b, i);
                push_tok(&mut out, l0, TokKind::Ident, &src[start..i]);
            }
            c if c.is_ascii_digit() => {
                let l0 = line;
                let start = i;
                i += 1;
                while i < n {
                    if is_ident_cont(b[i]) {
                        i += 1;
                    } else if b[i] == b'.' && i + 1 < n && b[i + 1].is_ascii_digit() {
                        // `1.5` continues the number; `1.max(…)` and `0..10`
                        // stop at the dot.
                        i += 1;
                    } else {
                        break;
                    }
                }
                push_tok(&mut out, l0, TokKind::Num, &src[start..i]);
            }
            _ => {
                let l0 = line;
                // Punctuation is emitted one char at a time; multi-char
                // operators (`::`, `=>`, `..`) are matched as sequences by
                // the rules that care.
                let ch = src[i..].chars().next().unwrap_or('\u{FFFD}');
                push_tok(&mut out, l0, TokKind::Punct(ch), &src[i..i + ch.len_utf8()]);
                i += ch.len_utf8();
            }
        }
    }

    // Second pass over tokens: attribute-line classification.
    let mut first_on_line: Option<u32> = None;
    for t in &out.toks {
        if first_on_line != Some(t.line) {
            first_on_line = Some(t.line);
            if t.is_punct('#') {
                mark(&mut out.lines, t.line).starts_attr = true;
            }
        }
    }
    out
}

fn push_tok(out: &mut Lexed, line: u32, kind: TokKind, text: &str) {
    let idx = line as usize;
    if out.lines.len() <= idx {
        out.lines.resize(idx + 1, LineInfo::default());
    }
    out.lines[idx].has_code = true;
    out.toks.push(Tok {
        line,
        kind,
        text: text.to_string(),
    });
}

fn scan_ident(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && is_ident_cont(b[i]) {
        i += 1;
    }
    i
}

/// Scan a `"…"` string starting at the opening quote; returns the index one
/// past the closing quote (or EOF for unterminated strings).
fn scan_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    debug_assert_eq!(b[i], b'"');
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Scan a raw string `r"…"` / `r#"…"#` starting at the `r`; returns `None`
/// if the text at `i` is not actually a raw string opener.
fn try_scan_raw_string(b: &[u8], i: usize, line: &mut u32) -> Option<usize> {
    debug_assert_eq!(b[i], b'r');
    let mut j = i + 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
        } else if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < b.len() && b[k] == b'#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some(k);
            }
            j += 1;
        } else {
            j += 1;
        }
    }
    Some(j)
}

/// Disambiguate `'x'` (char literal) from `'label` (lifetime/loop label),
/// starting at the quote. Returns the end index and the token kind.
fn scan_char_or_lifetime(b: &[u8], i: usize) -> (usize, TokKind) {
    debug_assert_eq!(b[i], b'\'');
    let n = b.len();
    let j = i + 1;
    if j >= n {
        return (j, TokKind::Char);
    }
    if b[j] == b'\\' {
        // Escaped char literal: scan to the closing quote.
        let mut k = j + 1;
        while k < n {
            if b[k] == b'\\' {
                k += 2;
            } else if b[k] == b'\'' {
                return (k + 1, TokKind::Char);
            } else {
                k += 1;
            }
        }
        return (k, TokKind::Char);
    }
    // One (possibly multi-byte) char followed by a quote is a char literal;
    // anything else is a lifetime or loop label.
    let ch_len = utf8_len(b[j]);
    let k = j + ch_len;
    if k < n && b[k] == b'\'' {
        return (k + 1, TokKind::Char);
    }
    let mut k = j;
    while k < n && is_ident_cont(b[k]) {
        k += 1;
    }
    (k, TokKind::Lifetime)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_are_not_tokens() {
        let l = lex("// unwrap\n/* expect */ let x = 1;\n");
        assert!(l.toks.iter().all(|t| t.text != "unwrap" && t.text != "expect"));
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
    }

    #[test]
    fn strings_hide_their_contents() {
        let l = lex(r##"let s = r#"unsafe { panic!() }"#; let t = "unwrap()";"##);
        assert!(l.toks.iter().all(|t| t.text != "unsafe" && t.text != "panic" && t.text != "unwrap"));
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
    }

    #[test]
    fn char_vs_lifetime() {
        let l = lex("let c = 'x'; fn f<'a>(v: &'a str) {} 'outer: loop { break 'outer; }");
        let chars: Vec<_> = l.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        let lifes: Vec<_> = l.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(chars.len(), 1);
        assert_eq!(chars[0].text, "'x'");
        assert_eq!(lifes.len(), 4); // 'a twice, 'outer twice
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_methods() {
        let l = lex("for i in 0..10 { let y = 1.max(2); let z = 2.5; }");
        let nums: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, ["0", "10", "1", "2", "2.5"]);
        assert!(idents("let y = 1.max(2);").contains(&"max".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("/* outer /* inner */ still comment */ fn f() {}");
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].text.contains("inner"));
        assert!(idents("/* a /* b */ c */ fn f() {}").contains(&"f".to_string()));
    }

    #[test]
    fn line_shapes() {
        let l = lex("// just a comment\n#[inline]\nfn f() {}\n\n");
        assert!(l.line(1).has_comment && !l.line(1).has_code);
        assert!(l.line(2).starts_attr && l.line(2).has_code);
        assert!(l.line(3).has_code && !l.line(3).starts_attr);
        assert!(!l.line(4).has_code && !l.line(4).has_comment);
    }

    #[test]
    fn byte_and_raw_forms() {
        let l = lex(r#"let a = b"bytes"; let c = b'\n'; let d = br"raw";"#);
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
    }
}
