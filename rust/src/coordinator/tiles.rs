//! Tile manager: shards stored words across fixed-geometry COSIME tiles and
//! merges per-tile winners — the hierarchical WTA composition of multiple
//! physical arrays (paper §3.5: per-array WTAs race locally; the global
//! winner is the max of local winners, valid because cosine scores are
//! absolute X²/Y values, not rank-only).
//!
//! Top-k composes the same way: each tile keeps its local best-k (iterated
//! WTA with inhibition), and the global best-k is the k best of the union —
//! [`TileManager::search_block`] runs the per-tile kernels over tile×batch
//! work slots in parallel, then merges the bounded selector buffers. All
//! slot buffers live in a caller-held [`TileScratch`] and are reused, so the
//! steady-state serving loop performs zero per-query allocations.
//!
//! # Live mutation and epoch coherence
//!
//! The tile set is mutable: [`TileManager::update_row`] /
//! [`TileManager::insert_row`] / [`TileManager::delete_row`] apply live
//! class-vector changes. Coherence is generation-based: every mutation
//! commits under the write half of an `RwLock` and bumps the *epoch*
//! counter; every batched search holds the read half for the whole block,
//! so an in-flight batch always sees one consistent snapshot — a tile can
//! grow, shrink or rebalance between batches but never under one.
//! [`TileManager::search_block`] returns the epoch it served so responses
//! can be stamped.
//!
//! Mutations prefer the engines' *incremental repack*
//! ([`AmEngine::update_row`] and friends — the packed-store engines patch
//! their fused u64 matrix in O(word) without rebuilding); engines that
//! cannot mutate in place (analog dies, fixed XLA artifacts) fall back to
//! rebuilding just the affected tile through the stored factory.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use anyhow::{bail, Result};

use crate::am::{
    AmEngine, BlockMatches, BlockSink, BlockTopK, QueriesRef, QueryBlock, SearchResult,
    SearchScratch,
};
use crate::util::sync::{TrackedRwLock, TILES_STORE};
use crate::util::{par, BitVec};

/// Engine constructor used to build tiles and to rebuild one tile when its
/// engine cannot apply a mutation in place.
pub type TileFactory = Box<dyn Fn(Vec<BitVec>) -> Result<Box<dyn AmEngine>> + Send + Sync>;

/// Typed compare-and-swap rejection: a mutation carried an `expected_epoch`
/// that no longer matched the store epoch *under the commit lock* — another
/// writer got in between. The store is unchanged. Travels inside the
/// `anyhow` chain; callers recover it with `downcast_ref::<EpochMismatch>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochMismatch {
    /// The epoch the caller expected.
    pub expected: u64,
    /// The epoch actually observed under the write lock.
    pub actual: u64,
}

impl std::fmt::Display for EpochMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "epoch mismatch: expected {}, store is at {} (concurrent commit)",
            self.expected, self.actual
        )
    }
}

impl std::error::Error for EpochMismatch {}

/// One consistent snapshot of the sharded store: `tiles[i]` stores rows
/// `[offsets[i], offsets[i+1])`, with `words` the per-tile source of truth
/// (kept for rebuilds and snapshot persistence of a live server).
struct TileSet {
    tiles: Vec<Box<dyn AmEngine>>,
    words: Vec<Vec<BitVec>>,
    offsets: Vec<usize>,
    total_rows: usize,
}

impl TileSet {
    /// (tile, local row) owning global `row`. Caller guarantees bounds.
    fn tile_of(&self, row: usize) -> (usize, usize) {
        let t = self.offsets.partition_point(|&o| o <= row) - 1;
        (t, row - self.offsets[t])
    }
}

/// Outcome of one committed mutation, captured under the same write lock
/// that ordered it — epoch, row count and engine capability are mutually
/// consistent (reading them afterwards could interleave with a concurrent
/// writer's commit).
#[derive(Debug, Clone, Copy)]
pub struct Commit {
    /// Store epoch after this commit.
    pub epoch: u64,
    /// Total stored rows after this commit.
    pub rows: usize,
    /// Deepest per-query k every tile can serve after this commit.
    pub max_k: usize,
}

/// A sharded, live-updatable AM (see module docs for coherence semantics).
pub struct TileManager {
    /// The epoch lock: the `tiles.store` class in
    /// [`crate::util::sync::lock_order`], poison-*propagating* (module
    /// docs). Commits take the write half inside a `// lint: epoch-write`
    /// region; searches share the read half.
    tiles: TrackedRwLock<TileSet>,
    factory: TileFactory,
    tile_capacity: usize,
    dims: usize,
    /// Generation counter: bumped once per committed mutation, read by
    /// every search under the same lock that orders the mutations.
    epoch: AtomicU64,
    /// Cached min-fold of the tile engines' `max_k`, refreshed by every
    /// commit *while the write lock is held* (so racing admins cannot leave
    /// a stale value behind). Lets the submit hot path gate on engine
    /// capability with one atomic load instead of a lock + O(tiles) fold.
    max_k_cache: AtomicUsize,
    /// Cached all-fold of the tile engines' `supports_threshold`, maintained
    /// exactly like `max_k_cache`: refreshed by every commit under the write
    /// lock, read lock-free by the submit gate. Threshold queries are served
    /// only while *every* tile can enumerate its match set.
    threshold_cache: AtomicBool,
}

/// One tile×batch work slot: a query range against one tile, with its own
/// reusable engine scratch and selector buffer.
struct TileSlot {
    tile: usize,
    q0: usize,
    q1: usize,
    scratch: SearchScratch,
    out: BlockTopK,
    matches: BlockMatches,
}

impl TileSlot {
    fn new() -> Self {
        TileSlot {
            tile: 0,
            q0: 0,
            q1: 0,
            scratch: SearchScratch::new(),
            out: BlockTopK::new(),
            matches: BlockMatches::new(),
        }
    }
}

/// Caller-held, reusable scratch for [`TileManager::search_block`]: the
/// per-slot selector buffers and engine scratch. Hold one per worker thread
/// and reuse it for the worker's whole lifetime.
pub struct TileScratch {
    slots: Vec<TileSlot>,
}

impl TileManager {
    /// Shard `words` into tiles of at most `tile_capacity` rows, building
    /// each tile with `factory` (pluggable engine backend). The factory is
    /// retained for live mutations: tiles whose engine cannot mutate in
    /// place are rebuilt through it.
    pub fn build(
        words: Vec<BitVec>,
        tile_capacity: usize,
        factory: impl Fn(Vec<BitVec>) -> Result<Box<dyn AmEngine>> + Send + Sync + 'static,
    ) -> Result<TileManager> {
        assert!(tile_capacity >= 1, "tile capacity must be positive");
        assert!(!words.is_empty(), "tile manager needs stored words");
        let dims = words[0].len();
        let total_rows = words.len();
        let mut tiles = Vec::new();
        let mut tile_words = Vec::new();
        let mut offsets = vec![0usize];
        let mut remaining = words;
        while !remaining.is_empty() {
            let take = remaining.len().min(tile_capacity);
            let rest = remaining.split_off(take);
            tiles.push(factory(remaining.clone())?);
            tile_words.push(remaining);
            // lint: allow(no-panic) -- offsets starts as vec![0], so last() is always Some.
            offsets.push(offsets.last().unwrap() + take);
            remaining = rest;
        }
        let max_k = tiles.iter().map(|t| t.max_k()).min().unwrap_or(usize::MAX);
        let thresholds = tiles.iter().all(|t| t.supports_threshold());
        Ok(TileManager {
            tiles: TrackedRwLock::new(
                &TILES_STORE,
                TileSet { tiles, words: tile_words, offsets, total_rows },
            ),
            factory: Box::new(factory),
            tile_capacity,
            dims,
            epoch: AtomicU64::new(0),
            max_k_cache: AtomicUsize::new(max_k),
            threshold_cache: AtomicBool::new(thresholds),
        })
    }

    /// Number of tiles currently backing the store.
    pub fn tile_count(&self) -> usize {
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        self.tiles.read().unwrap().tiles.len()
    }

    /// Total stored rows across tiles.
    pub fn rows(&self) -> usize {
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        self.tiles.read().unwrap().total_rows
    }

    /// Word width in bits.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Current store generation (bumped by every committed mutation).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Deepest per-query k every tile can serve (min over tile engines;
    /// e.g. 1 when any tile is a fixed-argmax XLA artifact). The service
    /// rejects deeper requests at submit time. One atomic load — the value
    /// is maintained by every commit under the write lock.
    pub fn max_k(&self) -> usize {
        self.max_k_cache.load(Ordering::Acquire)
    }

    /// Whether every tile can enumerate threshold match sets (false as soon
    /// as any tile is an argmax-only artifact, e.g. XLA). Same lock-free
    /// maintenance discipline as [`TileManager::max_k`].
    pub fn supports_threshold(&self) -> bool {
        self.threshold_cache.load(Ordering::Acquire)
    }

    /// Flat copy of every stored word in global row order — the persistence
    /// path of a live server (consistent: taken under the read lock).
    pub fn snapshot_words(&self) -> Vec<BitVec> {
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let set = self.tiles.read().unwrap();
        set.words.iter().flat_map(|w| w.iter().cloned()).collect()
    }

    /// One epoch-consistent slice of the stored words for snapshot
    /// streaming: `(epoch, total_rows, words[start..start+max])` in global
    /// row order. Epoch and rows are read under the same read guard that
    /// copies the words — commits take the write lock, so the three cannot
    /// tear against a concurrent mutation.
    pub fn snapshot_range(&self, start: usize, max: usize) -> (u64, usize, Vec<BitVec>) {
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let set = self.tiles.read().unwrap();
        let epoch = self.epoch.load(Ordering::Acquire);
        let total = set.total_rows;
        let rows = set
            .words
            .iter()
            .flat_map(|w| w.iter())
            .skip(start.min(total))
            .take(max)
            .cloned()
            .collect();
        (epoch, total, rows)
    }

    /// Overwrite the store epoch — a replica that just loaded a streamed
    /// snapshot seeds the primary's cut epoch here so catch-up replay and
    /// epoch-stamped responses line up with the primary's history. Never
    /// call this on a store already serving mutations.
    pub fn seed_epoch(&self, epoch: u64) {
        self.epoch.store(epoch, Ordering::Release);
    }

    /// Fresh (empty) scratch for [`TileManager::search_block`]; buffers grow
    /// on first use and are reused thereafter.
    pub fn scratch(&self) -> TileScratch {
        TileScratch { slots: Vec::new() }
    }

    // ---- live mutation (write side of the epoch lock) --------------------

    /// Bump the epoch and capture the commit outcome while still holding
    /// the write guard, so epoch/rows/max_k cannot interleave with another
    /// writer's commit. Also refreshes [`TileManager::max_k`]'s cache —
    /// writers are serialized here, so the cache always reflects the
    /// latest committed tile set.
    fn commit(&self, set: &TileSet) -> Commit {
        let max_k = set.tiles.iter().map(|t| t.max_k()).min().unwrap_or(usize::MAX);
        self.max_k_cache.store(max_k, Ordering::Release);
        let thresholds = set.tiles.iter().all(|t| t.supports_threshold());
        self.threshold_cache.store(thresholds, Ordering::Release);
        Commit {
            epoch: self.epoch.fetch_add(1, Ordering::AcqRel) + 1,
            rows: set.total_rows,
            max_k,
        }
    }

    /// While holding the write lock: reject the mutation if the caller
    /// pinned an expected epoch and a concurrent writer moved it. Writers
    /// are serialized by the lock, so this check-then-commit is atomic.
    fn check_expected_epoch(&self, expected: Option<u64>) -> Result<()> {
        if let Some(expected) = expected {
            let actual = self.epoch.load(Ordering::Acquire);
            if expected != actual {
                return Err(anyhow::Error::new(EpochMismatch { expected, actual }));
            }
        }
        Ok(())
    }

    /// Reprogram global row `row` to `word`. In-place incremental repack
    /// when the tile engine supports it, tile rebuild otherwise.
    pub fn update_row(&self, row: usize, word: &BitVec) -> Result<Commit> {
        self.update_row_cas(row, word, None)
    }

    /// [`TileManager::update_row`] with an optional compare-and-swap guard:
    /// with `expected_epoch = Some(e)`, the mutation commits only if the
    /// store epoch still equals `e` under the write lock; otherwise it is
    /// rejected with a typed [`EpochMismatch`] and the store is unchanged.
    pub fn update_row_cas(
        &self,
        row: usize,
        word: &BitVec,
        expected_epoch: Option<u64>,
    ) -> Result<Commit> {
        if word.len() != self.dims {
            bail!("word has {} bits, engine expects {}", word.len(), self.dims);
        }
        // lint: epoch-write -- mutation region: write half of the epoch lock, committed below.
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let mut set = self.tiles.write().unwrap();
        self.check_expected_epoch(expected_epoch)?;
        if row >= set.total_rows {
            bail!("row {row} out of range {}", set.total_rows);
        }
        let (t, local) = set.tile_of(row);
        if !set.tiles[t].update_row(local, word) {
            let mut ws = set.words[t].clone();
            ws[local] = word.clone();
            set.tiles[t] = (self.factory)(ws)?;
        }
        set.words[t][local] = word.clone();
        Ok(self.commit(&set))
        // lint: end-epoch-write
    }

    /// Append `word` as a new global row: into the last tile while it has
    /// capacity, otherwise a fresh tile is built (the store grows tile by
    /// tile, like racking another physical array). Returns (row, commit).
    pub fn insert_row(&self, word: &BitVec) -> Result<(usize, Commit)> {
        self.insert_row_cas(word, None)
    }

    /// [`TileManager::insert_row`] with the optional compare-and-swap guard
    /// (see [`TileManager::update_row_cas`]).
    pub fn insert_row_cas(
        &self,
        word: &BitVec,
        expected_epoch: Option<u64>,
    ) -> Result<(usize, Commit)> {
        if word.len() != self.dims {
            bail!("word has {} bits, engine expects {}", word.len(), self.dims);
        }
        // lint: epoch-write -- mutation region: write half of the epoch lock, committed below.
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let mut set = self.tiles.write().unwrap();
        self.check_expected_epoch(expected_epoch)?;
        let row = set.total_rows;
        let t = set.tiles.len() - 1;
        if set.words[t].len() < self.tile_capacity {
            if !set.tiles[t].push_row(word) {
                let mut ws = set.words[t].clone();
                ws.push(word.clone());
                set.tiles[t] = (self.factory)(ws)?;
            }
            set.words[t].push(word.clone());
            // lint: allow(no-panic) -- offsets starts as vec![0] and only grows, so last_mut() is always Some.
            *set.offsets.last_mut().unwrap() = row + 1;
        } else {
            let engine = (self.factory)(vec![word.clone()])?;
            set.tiles.push(engine);
            set.words.push(vec![word.clone()]);
            set.offsets.push(row + 1);
        }
        set.total_rows = row + 1;
        Ok((row, self.commit(&set)))
        // lint: end-epoch-write
    }

    /// Remove global row `row`; rows above shift down by one. A tile that
    /// empties is dropped whole. The last remaining row cannot be deleted
    /// (engines need at least one stored word).
    pub fn delete_row(&self, row: usize) -> Result<Commit> {
        self.delete_row_cas(row, None)
    }

    /// [`TileManager::delete_row`] with the optional compare-and-swap guard
    /// (see [`TileManager::update_row_cas`]).
    pub fn delete_row_cas(&self, row: usize, expected_epoch: Option<u64>) -> Result<Commit> {
        // lint: epoch-write -- mutation region: write half of the epoch lock, committed below.
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let mut set = self.tiles.write().unwrap();
        self.check_expected_epoch(expected_epoch)?;
        if row >= set.total_rows {
            bail!("row {row} out of range {}", set.total_rows);
        }
        if set.total_rows == 1 {
            bail!("cannot delete the last stored row");
        }
        let (t, local) = set.tile_of(row);
        if set.words[t].len() == 1 {
            set.tiles.remove(t);
            set.words.remove(t);
            set.offsets.remove(t + 1);
        } else {
            if !set.tiles[t].remove_row(local) {
                let mut ws = set.words[t].clone();
                ws.remove(local);
                set.tiles[t] = (self.factory)(ws)?;
            }
            set.words[t].remove(local);
        }
        for o in set.offsets.iter_mut().skip(t + 1) {
            *o -= 1;
        }
        set.total_rows -= 1;
        Ok(self.commit(&set))
        // lint: end-epoch-write
    }

    // ---- search (read side of the epoch lock) ----------------------------

    /// The hierarchical batched top-k kernel: every query of `queries`
    /// against every tile, results in `out` (one ranked selector per query,
    /// global row indices, k clamped to the store size). Returns the epoch
    /// of the snapshot served — the whole block scores against one
    /// consistent tile set even while writers queue.
    ///
    /// Work is decomposed into tile×batch slots filled in parallel (each
    /// slot is one tile against one contiguous query segment), then the
    /// bounded per-slot selectors are merged — the digital analogue of
    /// per-array WTAs racing locally before the global race. Single-tile and
    /// single-query calls take a serial fast path that feeds `out` directly
    /// with no intermediate buffers.
    pub fn search_block(
        &self,
        queries: QueriesRef<'_>,
        k: usize,
        scratch: &mut TileScratch,
        out: &mut BlockTopK,
    ) -> u64 {
        assert_eq!(queries.dims(), self.dims, "query dims mismatch");
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let guard = self.tiles.read().unwrap();
        let set: &TileSet = &guard;
        let epoch = self.epoch.load(Ordering::Acquire);
        let kk = k.min(set.total_rows);
        out.reset(queries.len(), kk);
        if queries.is_empty() || kk == 0 {
            return epoch;
        }

        let n_tiles = set.tiles.len();
        let threads = par::default_threads();
        if scratch.slots.is_empty() {
            scratch.slots.push(TileSlot::new());
        }

        // Serial fast path: offer every tile's rows straight into the global
        // selectors (TopK::offer *is* the merge); mirrors the seed's serial
        // per-tile loop but allocation-free and k-deep.
        // lint: hot-path
        if n_tiles == 1 || queries.len() == 1 || threads <= 1 {
            let slot = &mut scratch.slots[0];
            for (t, tile) in set.tiles.iter().enumerate() {
                tile.search_block(
                    queries,
                    set.offsets[t],
                    &mut slot.scratch,
                    BlockSink::TopK(out.selectors_mut()),
                );
            }
            return epoch;
        }
        // lint: end-hot-path

        // Parallel path: tile×batch slots. Segments along the batch axis
        // keep every core busy even when tiles are few.
        let segments = threads.div_ceil(n_tiles).clamp(1, queries.len());
        let needed = n_tiles * segments;
        while scratch.slots.len() < needed {
            scratch.slots.push(TileSlot::new());
        }
        // Steady-state parallel scoring: the slot pool above is the only
        // thing allowed to grow; everything from here to the merge reuses
        // warmed buffers.
        // lint: hot-path
        let mut i = 0;
        for tile in 0..n_tiles {
            for seg in 0..segments {
                let slot = &mut scratch.slots[i];
                i += 1;
                slot.tile = tile;
                slot.q0 = seg * queries.len() / segments;
                slot.q1 = (seg + 1) * queries.len() / segments;
                slot.out.reset(slot.q1 - slot.q0, kk);
            }
        }
        let slots = &mut scratch.slots[..needed];
        par::par_for_each_mut(slots, |_, slot| {
            if slot.q0 < slot.q1 {
                let sub = queries.slice(slot.q0, slot.q1);
                set.tiles[slot.tile].search_block(
                    sub,
                    set.offsets[slot.tile],
                    &mut slot.scratch,
                    BlockSink::TopK(slot.out.selectors_mut()),
                );
            }
        });
        // Hierarchical merge: per-slot bounded selectors into the global
        // per-query selectors (indices are already global via the offsets).
        for slot in slots.iter() {
            for (j, sel) in slot.out.selectors().iter().enumerate() {
                out.selectors_mut()[slot.q0 + j].merge_from(sel);
            }
        }
        // lint: end-hot-path
        epoch
    }

    /// The hierarchical batched *threshold* kernel: the range-query sibling
    /// of [`TileManager::search_block`]. The caller pre-resets `out` with one
    /// [`Matches`](crate::am::Matches) selector per query carrying that
    /// query's threshold and bound; this fills them with every stored row
    /// scoring `>= threshold` (best `bound` kept, typed truncation flag when
    /// a match set spills). Returns the epoch of the snapshot served.
    ///
    /// Exactness through the hierarchy: each tile enumerates its local match
    /// set under the *global* bound, and [`Matches::merge_from`]
    /// (crate::am::Matches::merge_from) guarantees the best-`bound` of
    /// per-tile best-`bound` sets equals the flat best-`bound` — with the
    /// truncation flag raised iff the flat match set exceeds the bound,
    /// whether the spill happened inside a tile or only at the merge.
    pub fn search_block_matches(
        &self,
        queries: QueriesRef<'_>,
        scratch: &mut TileScratch,
        out: &mut BlockMatches,
    ) -> u64 {
        assert_eq!(queries.dims(), self.dims, "query dims mismatch");
        assert_eq!(out.queries(), queries.len(), "selector count mismatch");
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let guard = self.tiles.read().unwrap();
        let set: &TileSet = &guard;
        let epoch = self.epoch.load(Ordering::Acquire);
        if queries.is_empty() {
            return epoch;
        }

        let n_tiles = set.tiles.len();
        let threads = par::default_threads();
        if scratch.slots.is_empty() {
            scratch.slots.push(TileSlot::new());
        }

        // Serial fast path: offer every tile's rows straight into the global
        // selectors (Matches::offer *is* the merge, spill flag included).
        // lint: hot-path
        if n_tiles == 1 || queries.len() == 1 || threads <= 1 {
            let slot = &mut scratch.slots[0];
            for (t, tile) in set.tiles.iter().enumerate() {
                tile.search_block(
                    queries,
                    set.offsets[t],
                    &mut slot.scratch,
                    BlockSink::Matches(out.selectors_mut()),
                );
            }
            return epoch;
        }
        // lint: end-hot-path

        // Parallel path: the same tile×batch slot grid as top-k, with each
        // slot selector inheriting its query's threshold/bound from `out`.
        let segments = threads.div_ceil(n_tiles).clamp(1, queries.len());
        let needed = n_tiles * segments;
        while scratch.slots.len() < needed {
            scratch.slots.push(TileSlot::new());
        }
        // lint: hot-path
        let mut i = 0;
        for tile in 0..n_tiles {
            for seg in 0..segments {
                let slot = &mut scratch.slots[i];
                i += 1;
                slot.tile = tile;
                slot.q0 = seg * queries.len() / segments;
                slot.q1 = (seg + 1) * queries.len() / segments;
                slot.matches.reset(slot.q1 - slot.q0, 0.0, 0);
                for (j, sel) in slot.matches.selectors_mut().iter_mut().enumerate() {
                    let src = &out.selectors()[slot.q0 + j];
                    sel.reset(src.threshold(), src.bound());
                }
            }
        }
        let slots = &mut scratch.slots[..needed];
        par::par_for_each_mut(slots, |_, slot| {
            if slot.q0 < slot.q1 {
                let sub = queries.slice(slot.q0, slot.q1);
                set.tiles[slot.tile].search_block(
                    sub,
                    set.offsets[slot.tile],
                    &mut slot.scratch,
                    BlockSink::Matches(slot.matches.selectors_mut()),
                );
            }
        });
        // Hierarchical merge: bounded per-slot match sets into the global
        // per-query selectors; truncation flags OR through.
        for slot in slots.iter() {
            for (j, sel) in slot.matches.selectors().iter().enumerate() {
                out.selectors_mut()[slot.q0 + j].merge_from(sel);
            }
        }
        // lint: end-hot-path
        epoch
    }

    /// Global threshold match set for one query (convenience; allocates its
    /// own buffers). Returns the bounded, rank-ordered matches and whether
    /// the set was truncated at `bound`.
    pub fn search_matches(
        &self,
        query: &BitVec,
        threshold: f64,
        bound: usize,
    ) -> (Vec<SearchResult>, bool) {
        assert_eq!(query.len(), self.dims, "query dims mismatch");
        let mut block = QueryBlock::new(self.dims);
        block.push(query);
        let mut scratch = self.scratch();
        let mut out = BlockMatches::new();
        out.reset(1, threshold, bound);
        self.search_block_matches(block.view(), &mut scratch, &mut out);
        (out.query(0).to_vec(), out.truncated(0))
    }

    /// Global top-k for one query (convenience; allocates its own buffers).
    pub fn search_topk(&self, query: &BitVec, k: usize) -> Vec<SearchResult> {
        assert_eq!(query.len(), self.dims, "query dims mismatch");
        let mut block = QueryBlock::new(self.dims);
        block.push(query);
        let mut scratch = self.scratch();
        let mut out = BlockTopK::new();
        self.search_block(block.view(), k, &mut scratch, &mut out);
        out.query(0).to_vec()
    }

    /// Batched global top-k (convenience; allocates its own buffers).
    pub fn search_topk_batch(&self, queries: &[BitVec], k: usize) -> Vec<Vec<SearchResult>> {
        let block = QueryBlock::pack(queries, self.dims);
        let mut scratch = self.scratch();
        let mut out = BlockTopK::new();
        self.search_block(block.view(), k, &mut scratch, &mut out);
        out.to_vecs()
    }

    /// Global NN search: per-tile fused WTA, then a max over local winners
    /// — allocation-free, and bit-for-bit the k = 1 head of the block
    /// kernel (same scores, same lowest-index tie-break; the property tests
    /// assert the equivalence).
    pub fn search(&self, query: &BitVec) -> SearchResult {
        assert_eq!(query.len(), self.dims, "query dims mismatch");
        // lint: allow(no-panic) -- a poisoned epoch lock means a mutator panicked mid-commit; serving or mutating a possibly-torn store would silently corrupt results, so propagating the panic is the correct policy.
        let set = self.tiles.read().unwrap();
        let mut best = SearchResult { winner: 0, score: f64::NEG_INFINITY };
        for (t, tile) in set.tiles.iter().enumerate() {
            let local = tile.search(query);
            if local.score > best.score {
                best = SearchResult { winner: set.offsets[t] + local.winner, score: local.score };
            }
        }
        best
    }

    /// Batched global search: one block through the tile×batch kernel with
    /// k = 1, per-tile merges running in parallel over reused buffers.
    pub fn search_batch(&self, queries: &[BitVec]) -> Vec<SearchResult> {
        let block = QueryBlock::pack(queries, self.dims);
        let mut scratch = self.scratch();
        let mut out = BlockTopK::new();
        self.search_block(block.view(), 1, &mut scratch, &mut out);
        out.selectors()
            .iter()
            // lint: allow(no-panic) -- the store is never empty (delete refuses the last row) and k is clamped to >= 1, so every selector holds at least one hit.
            .map(|sel| sel.best().expect("tile manager has rows").clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::{AmEngine, ApproxCosineEngine, DigitalExactEngine, HammingEngine};
    use crate::util::{prop, rng, BitVec};

    fn digital_factory(words: Vec<BitVec>) -> Result<Box<dyn AmEngine>> {
        Ok(Box::new(DigitalExactEngine::new(words)))
    }

    #[test]
    fn sharding_covers_all_rows() {
        let mut r = rng(1);
        let words: Vec<BitVec> = (0..100).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 32, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 4); // 32+32+32+4
        assert_eq!(tm.rows(), 100);
    }

    #[test]
    fn tiled_search_equals_flat_argmax_property() {
        // The core coordinator invariant: hierarchical WTA == flat argmax.
        prop::check("tiled == flat", 40, 2, |r| {
            let rows = 2 + r.below(60);
            let dims = 16 + 8 * r.below(8);
            let cap = 1 + r.below(rows);
            let words: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let flat = DigitalExactEngine::new(words.clone());
            let tm = TileManager::build(words, cap, digital_factory).map_err(|e| e.to_string())?;
            for _ in 0..5 {
                let q = BitVec::random(dims, 0.5, r);
                use crate::am::AmEngine;
                let f = flat.search(&q);
                let t = tm.search(&q);
                crate::prop_assert!(
                    (t.score - f.score).abs() < 1e-9,
                    "scores diverge: {} vs {}",
                    t.score,
                    f.score
                );
                // Winners may differ only on exact score ties.
                if t.winner != f.winner {
                    let s = flat.scores(&q);
                    crate::prop_assert!(
                        (s[t.winner] - s[f.winner]).abs() < 1e-9,
                        "non-tie winner mismatch"
                    );
                }
            }
            Ok(())
        });
    }

    /// End-to-end top-k invariant: tiled hierarchical top-k equals flat
    /// top-k for every k, engine, and tile capacity; k = 1 reproduces the
    /// flat single-winner search bit-for-bit.
    #[test]
    fn tiled_topk_equals_flat_topk_property() {
        prop::check("tiled topk == flat topk", 30, 6, |r| {
            let rows = 2 + r.below(60);
            let dims = 16 + 8 * r.below(8);
            let cap = 1 + r.below(rows);
            let k = 1 + r.below(8);
            let hamming = r.bool(0.5);
            let words: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let factory = move |w: Vec<BitVec>| -> Result<Box<dyn AmEngine>> {
                if hamming {
                    Ok(Box::new(HammingEngine::new(w)))
                } else {
                    Ok(Box::new(DigitalExactEngine::new(w)))
                }
            };
            let flat = factory(words.clone()).unwrap();
            let tm = TileManager::build(words, cap, factory).map_err(|e| e.to_string())?;
            let queries: Vec<BitVec> =
                (0..3 + r.below(6)).map(|_| BitVec::random(dims, 0.5, r)).collect();
            let tiled = tm.search_topk_batch(&queries, k);
            for (q, got) in queries.iter().zip(&tiled) {
                let want = flat.search_topk(q, k);
                crate::prop_assert!(
                    got.len() == want.len(),
                    "len {} vs {} (k {k}, cap {cap})",
                    got.len(),
                    want.len()
                );
                for (a, b) in got.iter().zip(&want) {
                    crate::prop_assert!(
                        a.winner == b.winner && a.score == b.score,
                        "tiled ({}, {}) vs flat ({}, {}) [k {k}, cap {cap}]",
                        a.winner,
                        a.score,
                        b.winner,
                        b.score
                    );
                }
                // k = 1 head must be bit-for-bit the flat single winner.
                let head = flat.search(q);
                crate::prop_assert!(
                    got[0].winner == head.winner && got[0].score == head.score,
                    "k=1 head diverges from flat search"
                );
            }
            Ok(())
        });
    }

    /// Threshold sibling of the top-k invariant: the hierarchically merged,
    /// bounded match set equals the flat engine's match set — entries,
    /// order, and truncation flag — for every tile capacity, including
    /// spills that only materialize at the merge (no tile locally truncates
    /// but the union exceeds the bound).
    #[test]
    fn tiled_threshold_equals_flat_matches_property() {
        prop::check("tiled threshold == flat matches", 30, 12, |r| {
            let rows = 2 + r.below(60);
            let dims = 16 + 8 * r.below(8);
            let cap = 1 + r.below(rows);
            let hamming = r.bool(0.5);
            let words: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let factory = move |w: Vec<BitVec>| -> Result<Box<dyn AmEngine>> {
                if hamming {
                    Ok(Box::new(HammingEngine::new(w)))
                } else {
                    Ok(Box::new(DigitalExactEngine::new(w)))
                }
            };
            let flat = factory(words.clone()).unwrap();
            let tm = TileManager::build(words, cap, factory).map_err(|e| e.to_string())?;
            crate::prop_assert!(tm.supports_threshold(), "digital tiles serve thresholds");

            let queries: Vec<BitVec> =
                (0..2 + r.below(6)).map(|_| BitVec::random(dims, 0.5, r)).collect();
            let bound = 1 + r.below(rows + 3);
            // Per-query thresholds drawn from each query's own score range so
            // match sets are non-trivially sized (empty and full included).
            let block = QueryBlock::pack(&queries, dims);
            let mut out = BlockMatches::new();
            out.reset(queries.len(), 0.0, bound);
            let mut thresholds = Vec::new();
            let mut scores = Vec::new();
            for (qi, q) in queries.iter().enumerate() {
                flat.scores_into(q, &mut scores);
                let lo = scores.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let d = lo + (hi - lo + 1.0) * (r.f64() * 1.3 - 0.1);
                thresholds.push(d);
                out.selectors_mut()[qi].reset(d, bound);
            }
            let mut scratch = tm.scratch();
            tm.search_block_matches(block.view(), &mut scratch, &mut out);
            for (qi, q) in queries.iter().enumerate() {
                let want = flat.search_matches(q, thresholds[qi], bound);
                crate::prop_assert!(
                    out.query(qi) == want.as_slice(),
                    "match set diverges (q {qi}, cap {cap}, bound {bound}): {:?} vs {:?}",
                    out.query(qi),
                    want.as_slice()
                );
                crate::prop_assert!(
                    out.truncated(qi) == want.truncated(),
                    "truncation flag diverges (q {qi}, cap {cap}, bound {bound})"
                );
                // Convenience single-query path agrees with the block path.
                let (single, trunc) = tm.search_matches(q, thresholds[qi], bound);
                crate::prop_assert!(
                    single.as_slice() == want.as_slice() && trunc == want.truncated(),
                    "single-query convenience diverges"
                );
            }
            Ok(())
        });
    }

    /// The threshold capability cache tracks tile composition across
    /// commits, exactly like `max_k`.
    #[test]
    fn threshold_capability_cache_follows_commits() {
        struct ArgmaxOnly(DigitalExactEngine);
        impl AmEngine for ArgmaxOnly {
            fn name(&self) -> &str {
                "argmax-only"
            }
            fn metric(&self) -> crate::am::Metric {
                self.0.metric()
            }
            fn rows(&self) -> usize {
                self.0.rows()
            }
            fn dims(&self) -> usize {
                self.0.dims()
            }
            fn scores_into(&self, query: &BitVec, out: &mut Vec<f64>) {
                self.0.scores_into(query, out)
            }
            fn supports_threshold(&self) -> bool {
                false
            }
        }
        let mut r = rng(29);
        let words: Vec<BitVec> = (0..6).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 3, |w| {
            Ok(Box::new(ArgmaxOnly(DigitalExactEngine::new(w))) as Box<dyn AmEngine>)
        })
        .unwrap();
        assert!(!tm.supports_threshold(), "argmax-only tiles cannot serve thresholds");
        let digital = TileManager::build(
            (0..6).map(|_| BitVec::random(32, 0.5, &mut r)).collect(),
            3,
            digital_factory,
        )
        .unwrap();
        assert!(digital.supports_threshold());
        // Commits keep the cache fresh.
        let w = BitVec::random(32, 0.5, &mut r);
        digital.update_row(0, &w).unwrap();
        assert!(digital.supports_threshold());
    }

    #[test]
    fn batch_matches_serial() {
        let mut r = rng(3);
        let words: Vec<BitVec> = (0..50).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 16, digital_factory).unwrap();
        let queries: Vec<BitVec> = (0..12).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let batch = tm.search_batch(&queries);
        for (q, b) in queries.iter().zip(&batch) {
            let s = tm.search(q);
            assert_eq!(s.winner, b.winner);
            assert_eq!(s.score, b.score);
        }
    }

    #[test]
    fn block_scratch_reuse_across_changing_batch_shapes() {
        let mut r = rng(7);
        let words: Vec<BitVec> = (0..80).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 24, digital_factory).unwrap();
        let mut block = QueryBlock::new(64);
        let mut scratch = tm.scratch();
        let mut out = BlockTopK::new();
        for round in 0..6 {
            let n = 1 + (round * 5) % 13;
            let queries: Vec<BitVec> = (0..n).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
            block.repack(&queries);
            let k = 1 + round % 4;
            tm.search_block(block.view(), k, &mut scratch, &mut out);
            let want = tm.search_topk_batch(&queries, k);
            assert_eq!(out.queries(), queries.len());
            for (qi, w) in want.iter().enumerate() {
                let got = out.query(qi);
                assert_eq!(got.len(), w.len(), "round {round} query {qi}");
                for (a, b) in got.iter().zip(w) {
                    assert_eq!(a.winner, b.winner);
                    assert_eq!(a.score, b.score);
                }
            }
        }
    }

    #[test]
    fn topk_k_clamps_to_store_size() {
        let mut r = rng(8);
        let words: Vec<BitVec> = (0..7).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 3, digital_factory).unwrap();
        let q = BitVec::random(32, 0.5, &mut r);
        assert_eq!(tm.search_topk(&q, 100).len(), 7);
        assert!(tm.search_topk(&q, 0).is_empty());
    }

    #[test]
    fn single_tile_passthrough() {
        let mut r = rng(4);
        let words: Vec<BitVec> = (0..10).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words.clone(), 1000, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 1);
        use crate::am::AmEngine;
        let flat = DigitalExactEngine::new(words);
        let q = BitVec::random(32, 0.5, &mut r);
        assert_eq!(tm.search(&q).winner, flat.search(&q).winner);
    }

    #[test]
    #[should_panic(expected = "dims mismatch")]
    fn wrong_query_dims_panics() {
        let mut r = rng(5);
        let words: Vec<BitVec> = (0..4).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 2, digital_factory).unwrap();
        let _ = tm.search(&BitVec::zeros(16));
    }

    // ---- live mutation ---------------------------------------------------

    /// Mirror-model property: any sequence of update/insert/delete applied
    /// to the tile manager matches a flat engine rebuilt from the mirrored
    /// word list — for both an in-place-capable engine (digital) and one
    /// that forces the tile-rebuild path (approx, which also re-freezes its
    /// norm, exercising the factory fallback equivalence).
    #[test]
    fn mutations_match_rebuilt_flat_reference() {
        prop::check("tile mutations == flat rebuild", 15, 9, |r| {
            let dims = 16 + 8 * r.below(6);
            let rows = 3 + r.below(30);
            let cap = 1 + r.below(12);
            let mut mirror: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let tm = TileManager::build(mirror.clone(), cap, digital_factory)
                .map_err(|e| e.to_string())?;
            let mut last_epoch = tm.epoch();
            for _ in 0..10 {
                match r.below(3) {
                    0 => {
                        let row = r.below(mirror.len());
                        let w = BitVec::random(dims, 0.2 + 0.6 * r.f64(), r);
                        mirror[row] = w.clone();
                        let c = tm.update_row(row, &w).map_err(|e| e.to_string())?;
                        crate::prop_assert!(c.epoch > last_epoch, "epoch must advance");
                        crate::prop_assert!(c.rows == mirror.len(), "commit row count");
                        last_epoch = c.epoch;
                    }
                    1 => {
                        let w = BitVec::random(dims, 0.2 + 0.6 * r.f64(), r);
                        mirror.push(w.clone());
                        let (row, c) = tm.insert_row(&w).map_err(|e| e.to_string())?;
                        crate::prop_assert!(row == mirror.len() - 1, "insert appends");
                        crate::prop_assert!(c.rows == mirror.len(), "commit row count");
                        last_epoch = c.epoch;
                    }
                    _ => {
                        if mirror.len() > 1 {
                            let row = r.below(mirror.len());
                            mirror.remove(row);
                            last_epoch =
                                tm.delete_row(row).map_err(|e| e.to_string())?.epoch;
                        }
                    }
                }
                crate::prop_assert!(tm.rows() == mirror.len(), "row count tracks mirror");
            }
            let flat = DigitalExactEngine::new(mirror.clone());
            let queries: Vec<BitVec> = (0..4).map(|_| BitVec::random(dims, 0.5, r)).collect();
            let k = 1 + r.below(6);
            let got = tm.search_topk_batch(&queries, k);
            for (q, hits) in queries.iter().zip(&got) {
                let want = flat.search_topk(q, k);
                crate::prop_assert!(hits.len() == want.len(), "result depth");
                for (a, b) in hits.iter().zip(&want) {
                    crate::prop_assert!(
                        a.winner == b.winner && a.score == b.score,
                        "mutated tiles ({}, {}) vs flat ({}, {})",
                        a.winner,
                        a.score,
                        b.winner,
                        b.score
                    );
                }
            }
            crate::prop_assert!(
                tm.snapshot_words() == mirror,
                "snapshot_words must equal the mirrored store"
            );
            Ok(())
        });
    }

    /// CAS mutations: a pinned expected epoch commits only while it still
    /// matches, and a stale pin is rejected with the typed
    /// [`EpochMismatch`] — atomically, under the same lock that orders
    /// commits.
    #[test]
    fn cas_mutations_check_epoch_under_the_lock() {
        let mut r = rng(23);
        let words: Vec<BitVec> = (0..10).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 4, digital_factory).unwrap();
        let w = BitVec::random(32, 0.5, &mut r);

        // Matching pin commits and advances the epoch.
        let e0 = tm.epoch();
        let c = tm.update_row_cas(1, &w, Some(e0)).unwrap();
        assert!(c.epoch > e0);

        // Stale pin: every mutation kind rejects with the typed error and
        // leaves epoch/rows unchanged.
        let rows_before = tm.rows();
        for result in [
            tm.update_row_cas(1, &w, Some(e0)).map(|_| ()),
            tm.insert_row_cas(&w, Some(e0)).map(|_| ()),
            tm.delete_row_cas(1, Some(e0)).map(|_| ()),
        ] {
            let err = result.expect_err("stale CAS must be rejected");
            let m = err.downcast_ref::<EpochMismatch>().expect("typed EpochMismatch");
            assert_eq!(m.expected, e0);
            assert_eq!(m.actual, c.epoch);
        }
        assert_eq!(tm.epoch(), c.epoch, "rejected CAS must not bump the epoch");
        assert_eq!(tm.rows(), rows_before, "rejected CAS must not mutate the store");

        // `None` keeps the unconditional behavior.
        assert!(tm.update_row_cas(2, &w, None).is_ok());
    }

    /// The factory-rebuild fallback path (engines without in-place
    /// mutation) must produce the same results as in-place repack.
    #[test]
    fn rebuild_fallback_matches_inplace_path() {
        struct Frozen(DigitalExactEngine);
        impl AmEngine for Frozen {
            fn name(&self) -> &str {
                "frozen"
            }
            fn metric(&self) -> crate::am::Metric {
                self.0.metric()
            }
            fn rows(&self) -> usize {
                self.0.rows()
            }
            fn dims(&self) -> usize {
                self.0.dims()
            }
            fn scores_into(&self, query: &BitVec, out: &mut Vec<f64>) {
                self.0.scores_into(query, out)
            }
            // No update_row/push_row/remove_row overrides: the tile manager
            // must fall back to rebuilding the tile via the factory.
        }
        let mut r = rng(17);
        let words: Vec<BitVec> = (0..20).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let frozen = TileManager::build(words.clone(), 6, |w| {
            Ok(Box::new(Frozen(DigitalExactEngine::new(w))) as Box<dyn AmEngine>)
        })
        .unwrap();
        let inplace = TileManager::build(words.clone(), 6, digital_factory).unwrap();

        let w = BitVec::random(64, 0.5, &mut r);
        frozen.update_row(13, &w).unwrap();
        inplace.update_row(13, &w).unwrap();
        let extra = BitVec::random(64, 0.5, &mut r);
        frozen.insert_row(&extra).unwrap();
        inplace.insert_row(&extra).unwrap();
        frozen.delete_row(2).unwrap();
        inplace.delete_row(2).unwrap();

        for _ in 0..10 {
            let q = BitVec::random(64, 0.5, &mut r);
            let a = frozen.search_topk(&q, 4);
            let b = inplace.search_topk(&q, 4);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.winner, y.winner);
                assert_eq!(x.score, y.score);
            }
        }
    }

    /// The approx engine re-freezes its store-wide denominator on mutation;
    /// through the tile manager it must stay identical to a fresh engine.
    #[test]
    fn approx_engine_refreezes_norm_through_tiles() {
        let mut r = rng(19);
        let words: Vec<BitVec> = (0..12).map(|_| BitVec::random(64, 0.3, &mut r)).collect();
        let tm = TileManager::build(words.clone(), 100, |w| {
            Ok(Box::new(ApproxCosineEngine::new(w)) as Box<dyn AmEngine>)
        })
        .unwrap();
        // A much denser word shifts E[Y]: the frozen denominator must follow.
        let dense = BitVec::from_bools(vec![true; 64]);
        tm.update_row(0, &dense).unwrap();
        let mut mirror = words;
        mirror[0] = dense;
        let fresh = ApproxCosineEngine::new(mirror);
        for _ in 0..10 {
            let q = BitVec::random(64, 0.5, &mut r);
            let a = tm.search_topk(&q, 3);
            let b = fresh.search_topk(&q, 3);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.winner, y.winner);
                assert_eq!(x.score, y.score, "re-frozen norm must match a fresh build");
            }
        }
    }

    #[test]
    fn insert_grows_tiles_and_delete_drops_empty_tiles() {
        let mut r = rng(21);
        let words: Vec<BitVec> = (0..6).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words.clone(), 3, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 2);

        // Filling the last tile then one more: a third tile appears.
        let w = BitVec::random(32, 0.5, &mut r);
        let (row, _) = tm.insert_row(&w).unwrap();
        assert_eq!(row, 6);
        assert_eq!(tm.tile_count(), 3);
        assert_eq!(tm.rows(), 7);
        assert_eq!(tm.search(&w).winner, 6, "new row is globally addressable");

        // Deleting the new tile's only row drops the tile entirely.
        tm.delete_row(6).unwrap();
        assert_eq!(tm.tile_count(), 2);
        assert_eq!(tm.rows(), 6);

        // Deleting from the middle shifts global indices down.
        let last = words[5].clone();
        tm.delete_row(0).unwrap();
        assert_eq!(tm.rows(), 5);
        assert_eq!(tm.search(&last).winner, 4, "indices above the hole shift down");

        // Guard rails.
        assert!(tm.update_row(99, &w).is_err());
        assert!(tm.delete_row(99).is_err());
        assert!(tm.insert_row(&BitVec::zeros(16)).is_err());
        for _ in 0..4 {
            let rows = tm.rows();
            tm.delete_row(rows - 1).unwrap();
        }
        assert_eq!(tm.rows(), 1);
        assert!(tm.delete_row(0).is_err(), "last row is undeletable");
    }

    /// Coherence under racing readers: batched searches concurrent with a
    /// writer must never observe a torn store — every response is exactly
    /// consistent with *some* epoch's snapshot, epochs are monotone per
    /// reader, and winners stay in bounds while rows come and go.
    #[test]
    fn racing_updates_never_tear_searches() {
        let dims = 128;
        let rows = 48;
        // Equal-popcount construction: every word in both generations has
        // exactly dims/2 ones, so any *consistent* snapshot bounds every
        // score by P = dims/2 (X ≤ P ⇒ X²/Y ≤ P). A torn row could only
        // arise from a racing repack, which the epoch lock forbids.
        let mut r = rng(23);
        let half_dense = |r: &mut crate::util::Rng| {
            let mut bits = vec![false; dims];
            for b in bits.iter_mut().take(dims / 2) {
                *b = true;
            }
            r.shuffle(&mut bits);
            BitVec::from_bools(bits)
        };
        let old: Vec<BitVec> = (0..rows).map(|_| half_dense(&mut r)).collect();
        let new: Vec<BitVec> = (0..rows).map(|_| half_dense(&mut r)).collect();
        let tm = TileManager::build(old.clone(), 12, digital_factory).unwrap();
        let p = (dims / 2) as f64;

        let done = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            let tm = &tm;
            let done = &done;
            let new = &new;
            let old = &old;
            s.spawn(move || {
                for (i, w) in new.iter().enumerate() {
                    tm.update_row(i, w).unwrap();
                }
                done.store(true, Ordering::Release);
            });
            for t in 0..3u64 {
                s.spawn(move || {
                    let mut r = rng(100 + t);
                    let mut block = QueryBlock::new(dims);
                    let mut scratch = tm.scratch();
                    let mut out = BlockTopK::new();
                    let mut last_epoch = 0u64;
                    while !done.load(Ordering::Acquire) {
                        let i = r.below(rows);
                        let queries = [old[i].clone(), new[i].clone()];
                        block.repack(queries.iter());
                        let epoch = tm.search_block(block.view(), 2, &mut scratch, &mut out);
                        assert!(epoch >= last_epoch, "epochs must be monotone per reader");
                        last_epoch = epoch;
                        for qi in 0..2 {
                            for hit in out.query(qi) {
                                assert!(hit.winner < rows, "winner in bounds");
                                assert!(
                                    hit.score <= p + 1e-9,
                                    "score {} exceeds the consistent-snapshot bound {p}",
                                    hit.score
                                );
                            }
                        }
                    }
                });
            }
        });
        // Quiesced: every row serves its new word exactly.
        for (i, w) in new.iter().enumerate() {
            let hit = tm.search(w);
            assert_eq!(hit.winner, i, "row {i} must serve its updated word");
            assert!((hit.score - p).abs() < 1e-9, "exact self-match score");
        }
        assert_eq!(tm.epoch(), rows as u64, "one epoch per committed update");
    }
}
