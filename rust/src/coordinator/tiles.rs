//! Tile manager: shards stored words across fixed-geometry COSIME tiles and
//! merges per-tile winners — the hierarchical WTA composition of multiple
//! physical arrays (paper §3.5: per-array WTAs race locally; the global
//! winner is the max of local winners, valid because cosine scores are
//! absolute X²/Y values, not rank-only).

use anyhow::Result;

use crate::am::{AmEngine, SearchResult};
use crate::util::BitVec;

/// A sharded AM: `tiles[i]` stores rows [offsets[i], offsets[i+1]).
pub struct TileManager {
    tiles: Vec<Box<dyn AmEngine>>,
    offsets: Vec<usize>,
    dims: usize,
    total_rows: usize,
}

impl TileManager {
    /// Shard `words` into tiles of at most `tile_capacity` rows, building
    /// each tile with `factory` (pluggable engine backend).
    pub fn build(
        words: Vec<BitVec>,
        tile_capacity: usize,
        factory: impl Fn(Vec<BitVec>) -> Result<Box<dyn AmEngine>>,
    ) -> Result<TileManager> {
        assert!(tile_capacity >= 1, "tile capacity must be positive");
        assert!(!words.is_empty(), "tile manager needs stored words");
        let dims = words[0].len();
        let total_rows = words.len();
        let mut tiles = Vec::new();
        let mut offsets = vec![0usize];
        let mut remaining = words;
        while !remaining.is_empty() {
            let take = remaining.len().min(tile_capacity);
            let rest = remaining.split_off(take);
            tiles.push(factory(remaining)?);
            offsets.push(offsets.last().unwrap() + take);
            remaining = rest;
        }
        Ok(TileManager { tiles, offsets, dims, total_rows })
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    pub fn rows(&self) -> usize {
        self.total_rows
    }

    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Global NN search: per-tile local WTA, then a max over local winners.
    pub fn search(&self, query: &BitVec) -> SearchResult {
        assert_eq!(query.len(), self.dims, "query dims mismatch");
        let mut best = SearchResult { winner: 0, score: f64::NEG_INFINITY };
        for (t, tile) in self.tiles.iter().enumerate() {
            let local = tile.search(query);
            if local.score > best.score {
                best = SearchResult { winner: self.offsets[t] + local.winner, score: local.score };
            }
        }
        best
    }

    /// Batched global search: per-tile batched execution, merged per query.
    pub fn search_batch(&self, queries: &[BitVec]) -> Vec<SearchResult> {
        let mut best: Vec<SearchResult> = queries
            .iter()
            .map(|_| SearchResult { winner: 0, score: f64::NEG_INFINITY })
            .collect();
        for (t, tile) in self.tiles.iter().enumerate() {
            let locals = tile.search_batch(queries);
            for (b, local) in locals.into_iter().enumerate() {
                if local.score > best[b].score {
                    best[b] =
                        SearchResult { winner: self.offsets[t] + local.winner, score: local.score };
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::DigitalExactEngine;
    use crate::util::{prop, rng, BitVec};

    fn digital_factory(words: Vec<BitVec>) -> Result<Box<dyn AmEngine>> {
        Ok(Box::new(DigitalExactEngine::new(words)))
    }

    #[test]
    fn sharding_covers_all_rows() {
        let mut r = rng(1);
        let words: Vec<BitVec> = (0..100).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 32, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 4); // 32+32+32+4
        assert_eq!(tm.rows(), 100);
    }

    #[test]
    fn tiled_search_equals_flat_argmax_property() {
        // The core coordinator invariant: hierarchical WTA == flat argmax.
        prop::check("tiled == flat", 40, 2, |r| {
            let rows = 2 + r.below(60);
            let dims = 16 + 8 * r.below(8);
            let cap = 1 + r.below(rows);
            let words: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let flat = DigitalExactEngine::new(words.clone());
            let tm = TileManager::build(words, cap, digital_factory).map_err(|e| e.to_string())?;
            for _ in 0..5 {
                let q = BitVec::random(dims, 0.5, r);
                use crate::am::AmEngine;
                let f = flat.search(&q);
                let t = tm.search(&q);
                crate::prop_assert!(
                    (t.score - f.score).abs() < 1e-9,
                    "scores diverge: {} vs {}",
                    t.score,
                    f.score
                );
                // Winners may differ only on exact score ties.
                if t.winner != f.winner {
                    let s = flat.scores(&q);
                    crate::prop_assert!(
                        (s[t.winner] - s[f.winner]).abs() < 1e-9,
                        "non-tie winner mismatch"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_matches_serial() {
        let mut r = rng(3);
        let words: Vec<BitVec> = (0..50).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 16, digital_factory).unwrap();
        let queries: Vec<BitVec> = (0..12).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let batch = tm.search_batch(&queries);
        for (q, b) in queries.iter().zip(&batch) {
            let s = tm.search(q);
            assert_eq!(s.winner, b.winner);
            assert_eq!(s.score, b.score);
        }
    }

    #[test]
    fn single_tile_passthrough() {
        let mut r = rng(4);
        let words: Vec<BitVec> = (0..10).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words.clone(), 1000, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 1);
        use crate::am::AmEngine;
        let flat = DigitalExactEngine::new(words);
        let q = BitVec::random(32, 0.5, &mut r);
        assert_eq!(tm.search(&q).winner, flat.search(&q).winner);
    }

    #[test]
    #[should_panic(expected = "dims mismatch")]
    fn wrong_query_dims_panics() {
        let mut r = rng(5);
        let words: Vec<BitVec> = (0..4).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 2, digital_factory).unwrap();
        let _ = tm.search(&BitVec::zeros(16));
    }
}
