//! Tile manager: shards stored words across fixed-geometry COSIME tiles and
//! merges per-tile winners — the hierarchical WTA composition of multiple
//! physical arrays (paper §3.5: per-array WTAs race locally; the global
//! winner is the max of local winners, valid because cosine scores are
//! absolute X²/Y values, not rank-only).
//!
//! Top-k composes the same way: each tile keeps its local best-k (iterated
//! WTA with inhibition), and the global best-k is the k best of the union —
//! [`TileManager::search_block`] runs the per-tile kernels over tile×batch
//! work slots in parallel, then merges the bounded selector buffers. All
//! slot buffers live in a caller-held [`TileScratch`] and are reused, so the
//! steady-state serving loop performs zero per-query allocations.

use anyhow::Result;

use crate::am::{AmEngine, BlockTopK, QueriesRef, QueryBlock, SearchResult, SearchScratch};
use crate::util::{par, BitVec};

/// A sharded AM: `tiles[i]` stores rows [offsets[i], offsets[i+1]).
pub struct TileManager {
    tiles: Vec<Box<dyn AmEngine>>,
    offsets: Vec<usize>,
    dims: usize,
    total_rows: usize,
}

/// One tile×batch work slot: a query range against one tile, with its own
/// reusable engine scratch and selector buffer.
struct TileSlot {
    tile: usize,
    q0: usize,
    q1: usize,
    scratch: SearchScratch,
    out: BlockTopK,
}

impl TileSlot {
    fn new() -> Self {
        TileSlot { tile: 0, q0: 0, q1: 0, scratch: SearchScratch::new(), out: BlockTopK::new() }
    }
}

/// Caller-held, reusable scratch for [`TileManager::search_block`]: the
/// per-slot selector buffers and engine scratch. Hold one per worker thread
/// and reuse it for the worker's whole lifetime.
pub struct TileScratch {
    slots: Vec<TileSlot>,
}

impl TileManager {
    /// Shard `words` into tiles of at most `tile_capacity` rows, building
    /// each tile with `factory` (pluggable engine backend).
    pub fn build(
        words: Vec<BitVec>,
        tile_capacity: usize,
        factory: impl Fn(Vec<BitVec>) -> Result<Box<dyn AmEngine>>,
    ) -> Result<TileManager> {
        assert!(tile_capacity >= 1, "tile capacity must be positive");
        assert!(!words.is_empty(), "tile manager needs stored words");
        let dims = words[0].len();
        let total_rows = words.len();
        let mut tiles = Vec::new();
        let mut offsets = vec![0usize];
        let mut remaining = words;
        while !remaining.is_empty() {
            let take = remaining.len().min(tile_capacity);
            let rest = remaining.split_off(take);
            tiles.push(factory(remaining)?);
            offsets.push(offsets.last().unwrap() + take);
            remaining = rest;
        }
        Ok(TileManager { tiles, offsets, dims, total_rows })
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    pub fn rows(&self) -> usize {
        self.total_rows
    }

    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Deepest per-query k every tile can serve (min over tile engines;
    /// e.g. 1 when any tile is a fixed-argmax XLA artifact). The service
    /// rejects deeper requests at submit time.
    pub fn max_k(&self) -> usize {
        self.tiles.iter().map(|t| t.max_k()).min().unwrap_or(usize::MAX)
    }

    /// Fresh (empty) scratch for [`TileManager::search_block`]; buffers grow
    /// on first use and are reused thereafter.
    pub fn scratch(&self) -> TileScratch {
        TileScratch { slots: Vec::new() }
    }

    /// The hierarchical batched top-k kernel: every query of `queries`
    /// against every tile, results in `out` (one ranked selector per query,
    /// global row indices, k clamped to the store size).
    ///
    /// Work is decomposed into tile×batch slots filled in parallel (each
    /// slot is one tile against one contiguous query segment), then the
    /// bounded per-slot selectors are merged — the digital analogue of
    /// per-array WTAs racing locally before the global race. Single-tile and
    /// single-query calls take a serial fast path that feeds `out` directly
    /// with no intermediate buffers.
    pub fn search_block(
        &self,
        queries: QueriesRef<'_>,
        k: usize,
        scratch: &mut TileScratch,
        out: &mut BlockTopK,
    ) {
        assert_eq!(queries.dims(), self.dims, "query dims mismatch");
        let kk = k.min(self.total_rows);
        out.reset(queries.len(), kk);
        if queries.is_empty() || kk == 0 {
            return;
        }

        let n_tiles = self.tiles.len();
        let threads = par::default_threads();
        if scratch.slots.is_empty() {
            scratch.slots.push(TileSlot::new());
        }

        // Serial fast path: offer every tile's rows straight into the global
        // selectors (TopK::offer *is* the merge); mirrors the seed's serial
        // per-tile loop but allocation-free and k-deep.
        if n_tiles == 1 || queries.len() == 1 || threads <= 1 {
            let slot = &mut scratch.slots[0];
            for (t, tile) in self.tiles.iter().enumerate() {
                tile.search_block(queries, self.offsets[t], &mut slot.scratch, out.selectors_mut());
            }
            return;
        }

        // Parallel path: tile×batch slots. Segments along the batch axis
        // keep every core busy even when tiles are few.
        let segments = threads.div_ceil(n_tiles).clamp(1, queries.len());
        let needed = n_tiles * segments;
        while scratch.slots.len() < needed {
            scratch.slots.push(TileSlot::new());
        }
        let mut i = 0;
        for tile in 0..n_tiles {
            for seg in 0..segments {
                let slot = &mut scratch.slots[i];
                i += 1;
                slot.tile = tile;
                slot.q0 = seg * queries.len() / segments;
                slot.q1 = (seg + 1) * queries.len() / segments;
                slot.out.reset(slot.q1 - slot.q0, kk);
            }
        }
        let slots = &mut scratch.slots[..needed];
        par::par_for_each_mut(slots, |_, slot| {
            if slot.q0 < slot.q1 {
                let sub = queries.slice(slot.q0, slot.q1);
                self.tiles[slot.tile].search_block(
                    sub,
                    self.offsets[slot.tile],
                    &mut slot.scratch,
                    slot.out.selectors_mut(),
                );
            }
        });
        // Hierarchical merge: per-slot bounded selectors into the global
        // per-query selectors (indices are already global via the offsets).
        for slot in slots.iter() {
            for (j, sel) in slot.out.selectors().iter().enumerate() {
                out.selectors_mut()[slot.q0 + j].merge_from(sel);
            }
        }
    }

    /// Global top-k for one query (convenience; allocates its own buffers).
    pub fn search_topk(&self, query: &BitVec, k: usize) -> Vec<SearchResult> {
        assert_eq!(query.len(), self.dims, "query dims mismatch");
        let mut block = QueryBlock::new(self.dims);
        block.push(query);
        let mut scratch = self.scratch();
        let mut out = BlockTopK::new();
        self.search_block(block.view(), k, &mut scratch, &mut out);
        out.query(0).to_vec()
    }

    /// Batched global top-k (convenience; allocates its own buffers).
    pub fn search_topk_batch(&self, queries: &[BitVec], k: usize) -> Vec<Vec<SearchResult>> {
        let block = QueryBlock::pack(queries, self.dims);
        let mut scratch = self.scratch();
        let mut out = BlockTopK::new();
        self.search_block(block.view(), k, &mut scratch, &mut out);
        out.to_vecs()
    }

    /// Global NN search: per-tile fused WTA, then a max over local winners
    /// — allocation-free, and bit-for-bit the k = 1 head of the block
    /// kernel (same scores, same lowest-index tie-break; the property tests
    /// assert the equivalence).
    pub fn search(&self, query: &BitVec) -> SearchResult {
        assert_eq!(query.len(), self.dims, "query dims mismatch");
        let mut best = SearchResult { winner: 0, score: f64::NEG_INFINITY };
        for (t, tile) in self.tiles.iter().enumerate() {
            let local = tile.search(query);
            if local.score > best.score {
                best = SearchResult { winner: self.offsets[t] + local.winner, score: local.score };
            }
        }
        best
    }

    /// Batched global search: one block through the tile×batch kernel with
    /// k = 1, per-tile merges running in parallel over reused buffers.
    pub fn search_batch(&self, queries: &[BitVec]) -> Vec<SearchResult> {
        let block = QueryBlock::pack(queries, self.dims);
        let mut scratch = self.scratch();
        let mut out = BlockTopK::new();
        self.search_block(block.view(), 1, &mut scratch, &mut out);
        out.selectors()
            .iter()
            .map(|sel| sel.best().expect("tile manager has rows").clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::{AmEngine, DigitalExactEngine, HammingEngine};
    use crate::util::{prop, rng, BitVec};

    fn digital_factory(words: Vec<BitVec>) -> Result<Box<dyn AmEngine>> {
        Ok(Box::new(DigitalExactEngine::new(words)))
    }

    #[test]
    fn sharding_covers_all_rows() {
        let mut r = rng(1);
        let words: Vec<BitVec> = (0..100).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 32, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 4); // 32+32+32+4
        assert_eq!(tm.rows(), 100);
    }

    #[test]
    fn tiled_search_equals_flat_argmax_property() {
        // The core coordinator invariant: hierarchical WTA == flat argmax.
        prop::check("tiled == flat", 40, 2, |r| {
            let rows = 2 + r.below(60);
            let dims = 16 + 8 * r.below(8);
            let cap = 1 + r.below(rows);
            let words: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let flat = DigitalExactEngine::new(words.clone());
            let tm = TileManager::build(words, cap, digital_factory).map_err(|e| e.to_string())?;
            for _ in 0..5 {
                let q = BitVec::random(dims, 0.5, r);
                use crate::am::AmEngine;
                let f = flat.search(&q);
                let t = tm.search(&q);
                crate::prop_assert!(
                    (t.score - f.score).abs() < 1e-9,
                    "scores diverge: {} vs {}",
                    t.score,
                    f.score
                );
                // Winners may differ only on exact score ties.
                if t.winner != f.winner {
                    let s = flat.scores(&q);
                    crate::prop_assert!(
                        (s[t.winner] - s[f.winner]).abs() < 1e-9,
                        "non-tie winner mismatch"
                    );
                }
            }
            Ok(())
        });
    }

    /// End-to-end top-k invariant: tiled hierarchical top-k equals flat
    /// top-k for every k, engine, and tile capacity; k = 1 reproduces the
    /// flat single-winner search bit-for-bit.
    #[test]
    fn tiled_topk_equals_flat_topk_property() {
        prop::check("tiled topk == flat topk", 30, 6, |r| {
            let rows = 2 + r.below(60);
            let dims = 16 + 8 * r.below(8);
            let cap = 1 + r.below(rows);
            let k = 1 + r.below(8);
            let hamming = r.bool(0.5);
            let words: Vec<BitVec> =
                (0..rows).map(|_| BitVec::random(dims, 0.2 + 0.6 * r.f64(), r)).collect();
            let factory = |w: Vec<BitVec>| -> Result<Box<dyn AmEngine>> {
                if hamming {
                    Ok(Box::new(HammingEngine::new(w)))
                } else {
                    Ok(Box::new(DigitalExactEngine::new(w)))
                }
            };
            let flat = factory(words.clone()).unwrap();
            let tm = TileManager::build(words, cap, factory).map_err(|e| e.to_string())?;
            let queries: Vec<BitVec> =
                (0..3 + r.below(6)).map(|_| BitVec::random(dims, 0.5, r)).collect();
            let tiled = tm.search_topk_batch(&queries, k);
            for (q, got) in queries.iter().zip(&tiled) {
                let want = flat.search_topk(q, k);
                crate::prop_assert!(
                    got.len() == want.len(),
                    "len {} vs {} (k {k}, cap {cap})",
                    got.len(),
                    want.len()
                );
                for (a, b) in got.iter().zip(&want) {
                    crate::prop_assert!(
                        a.winner == b.winner && a.score == b.score,
                        "tiled ({}, {}) vs flat ({}, {}) [k {k}, cap {cap}]",
                        a.winner,
                        a.score,
                        b.winner,
                        b.score
                    );
                }
                // k = 1 head must be bit-for-bit the flat single winner.
                let head = flat.search(q);
                crate::prop_assert!(
                    got[0].winner == head.winner && got[0].score == head.score,
                    "k=1 head diverges from flat search"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batch_matches_serial() {
        let mut r = rng(3);
        let words: Vec<BitVec> = (0..50).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 16, digital_factory).unwrap();
        let queries: Vec<BitVec> = (0..12).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let batch = tm.search_batch(&queries);
        for (q, b) in queries.iter().zip(&batch) {
            let s = tm.search(q);
            assert_eq!(s.winner, b.winner);
            assert_eq!(s.score, b.score);
        }
    }

    #[test]
    fn block_scratch_reuse_across_changing_batch_shapes() {
        let mut r = rng(7);
        let words: Vec<BitVec> = (0..80).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 24, digital_factory).unwrap();
        let mut block = QueryBlock::new(64);
        let mut scratch = tm.scratch();
        let mut out = BlockTopK::new();
        for round in 0..6 {
            let n = 1 + (round * 5) % 13;
            let queries: Vec<BitVec> = (0..n).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
            block.repack(&queries);
            let k = 1 + round % 4;
            tm.search_block(block.view(), k, &mut scratch, &mut out);
            let want = tm.search_topk_batch(&queries, k);
            assert_eq!(out.queries(), queries.len());
            for (qi, w) in want.iter().enumerate() {
                let got = out.query(qi);
                assert_eq!(got.len(), w.len(), "round {round} query {qi}");
                for (a, b) in got.iter().zip(w) {
                    assert_eq!(a.winner, b.winner);
                    assert_eq!(a.score, b.score);
                }
            }
        }
    }

    #[test]
    fn topk_k_clamps_to_store_size() {
        let mut r = rng(8);
        let words: Vec<BitVec> = (0..7).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 3, digital_factory).unwrap();
        let q = BitVec::random(32, 0.5, &mut r);
        assert_eq!(tm.search_topk(&q, 100).len(), 7);
        assert!(tm.search_topk(&q, 0).is_empty());
    }

    #[test]
    fn single_tile_passthrough() {
        let mut r = rng(4);
        let words: Vec<BitVec> = (0..10).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words.clone(), 1000, digital_factory).unwrap();
        assert_eq!(tm.tile_count(), 1);
        use crate::am::AmEngine;
        let flat = DigitalExactEngine::new(words);
        let q = BitVec::random(32, 0.5, &mut r);
        assert_eq!(tm.search(&q).winner, flat.search(&q).winner);
    }

    #[test]
    #[should_panic(expected = "dims mismatch")]
    fn wrong_query_dims_panics() {
        let mut r = rng(5);
        let words: Vec<BitVec> = (0..4).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tm = TileManager::build(words, 2, digital_factory).unwrap();
        let _ = tm.search(&BitVec::zeros(16));
    }
}
