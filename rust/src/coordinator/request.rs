//! Request/response types for the serving path.

use std::time::Duration;

/// Why a submission was rejected.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum SubmitError {
    /// Bounded queue is full — backpressure; retry later.
    #[error("queue full (backpressure)")]
    Busy,
    /// Service is shutting down.
    #[error("service closed")]
    Closed,
    /// Query malformed (e.g. wrong dimensionality).
    #[error("bad query: {0}")]
    BadQuery(String),
}

/// Per-request timing, filled by the service.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// Time spent waiting in the batch queue.
    pub queued: Duration,
    /// Time in engine execution (shared across the batch).
    pub exec: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// A completed search.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    /// Global winning row index (across all tiles).
    pub winner: usize,
    /// Winning score in the engine metric.
    pub score: f64,
    pub timing: RequestTiming,
}
