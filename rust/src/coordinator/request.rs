//! Request/response types for the serving path: search submissions plus the
//! admin plane (live class-vector updates through the write-verify path).

use std::time::Duration;

use crate::am::write::WriteReport;
use crate::am::SearchResult;
use crate::util::BitVec;

use super::metrics::AdminKind;

/// Why a submission was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue is full — backpressure; retry later.
    Busy,
    /// Service is shutting down.
    Closed,
    /// Query malformed (e.g. wrong dimensionality or k = 0).
    BadQuery(String),
    /// Admin write rejected: cells failed read-verify after the retry
    /// budget — the word was *not* applied to the serving store.
    WriteFailed(String),
    /// Admin compare-and-swap rejected: the op carried an `expected_epoch`
    /// that no longer matches the owning shard's epoch — another writer
    /// committed in between. The store is unchanged; re-read and retry.
    EpochMismatch {
        /// The epoch the caller expected the owning shard to be at.
        expected: u64,
        /// The shard epoch actually observed under the commit lock.
        actual: u64,
    },
    /// The connection has not completed the shared-secret hello handshake
    /// (or presented the wrong secret) against a server that configures
    /// `[server] auth_secret`. Hello and retry.
    Unauthorized,
    /// A catch-up pull asked for epochs the bounded replication log has
    /// already evicted. Restart from a full snapshot.
    LogTruncated {
        /// Oldest epoch the log can still replay *from* (exclusive): pulls
        /// with `from_epoch >= floor` succeed.
        floor: u64,
    },
    /// Transport failure talking to a remote backend (connection refused,
    /// reset, or a protocol-level frame error). The request may or may not
    /// have reached the server.
    Io(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::BadQuery(msg) => write!(f, "bad query: {msg}"),
            SubmitError::WriteFailed(msg) => write!(f, "write failed: {msg}"),
            SubmitError::EpochMismatch { expected, actual } => write!(
                f,
                "epoch mismatch: expected shard epoch {expected}, store is at {actual}"
            ),
            SubmitError::Unauthorized => {
                write!(f, "unauthorized: hello handshake required or secret mismatch")
            }
            SubmitError::LogTruncated { floor } => write!(
                f,
                "catch-up log truncated: oldest replayable epoch is {floor}, take a full snapshot"
            ),
            SubmitError::Io(msg) => write!(f, "backend i/o: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-request timing, filled by the service.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// Time spent waiting in the batch queue.
    pub queued: Duration,
    /// Time in engine execution (shared across the batch).
    pub exec: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// A completed search, for either query kind.
///
/// Top-k responses carry the ranked `min(k, rows)` winners and never set
/// `truncated`. Threshold responses carry every row scoring at or above the
/// requested threshold, rank-ordered and capped at the request's `limit`;
/// `truncated` is the typed spill flag. A threshold query can legitimately
/// match nothing — then `hits` is empty and `winner`/`score` degrade to
/// `0` / `-inf`.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    /// Global winning row index (across all tiles) — the head of `hits`,
    /// or 0 when a threshold query matched nothing.
    pub winner: usize,
    /// Winning score in the engine metric — the head of `hits`, or
    /// `f64::NEG_INFINITY` when a threshold query matched nothing.
    pub score: f64,
    /// Ranked winners, best first: `min(k, rows)` entries for top-k, the
    /// bounded match set for threshold — global row indices either way
    /// (the iterated-WTA-with-inhibition readout of §3.5).
    pub hits: Vec<SearchResult>,
    /// Threshold queries only: true when the match set exceeded the
    /// request's `limit` and was cut to the best `limit` rows. Always false
    /// for top-k.
    pub truncated: bool,
    /// Store epoch this search was served at: the whole batch scored one
    /// consistent snapshot of the (possibly live-updating) tile set.
    pub epoch: u64,
    /// Queue/exec/batch breakdown of this request's latency.
    pub timing: RequestTiming,
}

/// An admin-plane mutation of the serving store. Update/Insert words pass
/// through the §4 ±4 V write-verify programming path first, so what the
/// store serves is what the array would actually read back — and the
/// response carries the pulse-accurate write cost.
#[derive(Debug, Clone)]
pub enum AdminOp {
    /// Reprogram stored row `row` to `word`.
    Update { row: usize, word: BitVec },
    /// Append `word` as a new row (tiles grow as needed).
    Insert { word: BitVec },
    /// Remove stored row `row`; rows above shift down by one.
    Delete { row: usize },
}

impl AdminOp {
    /// Metrics lane this op lands in.
    pub fn kind(&self) -> AdminKind {
        match self {
            AdminOp::Update { .. } => AdminKind::Update,
            AdminOp::Insert { .. } => AdminKind::Insert,
            AdminOp::Delete { .. } => AdminKind::Delete,
        }
    }
}

/// Outcome of a committed [`AdminOp`].
#[derive(Debug, Clone)]
pub struct AdminResponse {
    /// Row the op affected (for Insert: the new global row index).
    pub row: usize,
    /// Store epoch after the commit — searches stamped with an epoch ≥ this
    /// are guaranteed to observe the mutation.
    pub epoch: u64,
    /// Total stored rows after the commit.
    pub rows: usize,
    /// Write-verify cost of the programming pass (None for Delete, which
    /// only retires rows).
    pub write: Option<WriteReport>,
}
