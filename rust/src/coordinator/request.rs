//! Request/response types for the serving path.

use std::time::Duration;

use crate::am::SearchResult;

/// Why a submission was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue is full — backpressure; retry later.
    Busy,
    /// Service is shutting down.
    Closed,
    /// Query malformed (e.g. wrong dimensionality or k = 0).
    BadQuery(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::BadQuery(msg) => write!(f, "bad query: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-request timing, filled by the service.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// Time spent waiting in the batch queue.
    pub queued: Duration,
    /// Time in engine execution (shared across the batch).
    pub exec: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// A completed search: the ranked winners the request's `k` asked for.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    /// Global winning row index (across all tiles) — the head of `hits`.
    pub winner: usize,
    /// Winning score in the engine metric — the head of `hits`.
    pub score: f64,
    /// Ranked winners, best first: `min(k, rows)` entries with global row
    /// indices (the iterated-WTA-with-inhibition readout of §3.5).
    pub hits: Vec<SearchResult>,
    pub timing: RequestTiming,
}
