//! The transport-agnostic serving surface: one completion-based [`Backend`]
//! trait that every way of reaching a COSIME store implements.
//!
//! The repo grew three incompatible serving surfaces — the in-process
//! [`AmService`], the scatter-gather
//! [`ShardRouter`](crate::server::ShardRouter), and the blocking TCP
//! [`Client`](crate::server::Client). Each forced its callers to commit to
//! a topology at compile time. The `Backend` trait collapses them into one
//! shape with *ticket/completion* semantics:
//!
//! * [`Backend::submit_search`] hands a whole query batch to the backend
//!   **without blocking** and returns a [`Ticket`];
//! * [`Ticket::poll`] asks whether the batch finished (also nonblocking);
//!   [`Ticket::wait`] blocks until it does — the adapter the legacy
//!   blocking call sites ride on;
//! * [`Backend::admin`], [`Backend::health`] and [`Backend::metrics`] are
//!   the control plane: synchronous, rare, and uniform across transports.
//!
//! Three implementations ship:
//!
//! * [`LocalBackend`] (here) — wraps an [`AmService`]; the completion is
//!   the service's existing per-request mpsc receiver.
//! * [`RouterBackend`](crate::server::RouterBackend) — fans a batch over
//!   `Box<dyn Backend>` children (in-process stacks *or* remote servers),
//!   merging ranked lists under the `shard << 48 | local` global-id
//!   scheme.
//! * [`RemoteBackend`](crate::server::RemoteBackend) — a nonblocking
//!   client for the `cosimed` wire protocol; the completion is an
//!   in-order response-frame slot on a shared connection.
//!
//! Because the TCP frontend ([`crate::server::tcp`]) serves from a
//! `dyn Backend`, a `cosimed` process is *one code path* whether it fronts
//! a single in-process store, S local shards, or a routing tier over
//! remote shard servers.
//!
//! # Row ids
//!
//! All rows crossing this surface are **global u64 ids**: for a flat store
//! they equal the local row index; a router encodes the owning child in
//! the high bits (see [`crate::server::shard`]). Hits come back with
//! global ids so callers can hand them straight to [`Backend::admin`].
//!
//! # Completion discipline
//!
//! A [`Ticket`] is single-shot: once [`Ticket::poll`] returns
//! `Ok(Some(result))` (or [`Ticket::wait`] returns), the ticket is spent
//! and must be dropped. Polling is cheap enough to sit in an event loop's
//! hot path.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::am::write::WriteReport;
use crate::util::BitVec;

use super::metrics::MetricsSnapshot;
use super::request::{AdminOp, SearchResponse, SubmitError};
use super::service::AmService;

/// One ranked hit as every backend reports it: a **global** row id plus the
/// engine-metric score. (The wire protocol re-exports this as `WireHit`.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Global row id of the hit.
    pub row: u64,
    /// Score in the engine's own metric (higher = closer).
    pub score: f64,
}

/// A completed search batch: one ranked hit list per submitted query, in
/// submission order, stamped with the highest (aggregate) epoch any query
/// in the batch was served at.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchResult {
    /// Highest aggregate epoch any query in the batch was served at.
    pub epoch: u64,
    /// One ranked hit list per query, in submission order.
    pub results: Vec<Vec<Hit>>,
    /// Per-query truncation flags, parallel to `results`: true when a
    /// threshold query's match set exceeded the request `limit` and was cut
    /// to the best `limit` rows. Always all-false for top-k batches.
    pub truncated: Vec<bool>,
    /// Degraded-scatter marker: true when a routing tier served this batch
    /// from fewer than all of its shards (ejected members excluded), so the
    /// hit lists are complete over the *surviving* shards only. Always
    /// false for flat backends.
    pub partial: bool,
}

/// A backend's identity and self-describing serving policy. The
/// `max_batch`/`max_k` fields are the *batching hints*: clients size their
/// frames from them instead of discovering limits through `BadQuery`
/// rejections. `0` means "unknown" (a pre-v2 peer that did not advertise).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendHealth {
    /// Total stored rows across shards.
    pub rows: u64,
    /// Word width in bits.
    pub dims: u64,
    /// Aggregate store epoch (sum over shards).
    pub epoch: u64,
    /// Shard count behind this backend (1 for a local store).
    pub shards: u32,
    /// Server-side dynamic batch cap — the sweet spot for frame sizing.
    pub max_batch: u32,
    /// Deepest top-k the backend will accept (policy ∩ engine capability).
    pub max_k: u32,
    /// Shards currently ejected from the scatter by health-based failover
    /// (0 for flat backends and pre-v4 peers). When nonzero, searches are
    /// served degraded with [`BatchResult::partial`] set.
    pub shards_unhealthy: u32,
}

/// Write-verify cost summary as it crosses the backend surface (the scalar
/// fields of [`WriteReport`]; per-round latencies stay server-side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WriteCost {
    /// Cells touched by the verified write.
    pub cells: u64,
    /// Program/verify pulses issued.
    pub pulses: u64,
    /// Cells still failing verify after the retry budget.
    pub failures: u64,
    /// Modeled write energy in joules.
    pub energy_j: f64,
    /// Modeled write latency in seconds.
    pub latency_s: f64,
}

impl WriteCost {
    /// Project the scalar cost out of a full programming report.
    pub fn from_report(r: &WriteReport) -> WriteCost {
        WriteCost {
            cells: r.cells as u64,
            pulses: r.pulses as u64,
            failures: r.failures as u64,
            energy_j: r.energy,
            latency_s: r.latency,
        }
    }
}

/// An admin mutation addressed in global row ids (contrast
/// [`AdminOp`], whose rows are service-local). The optional
/// compare-and-swap pin travels alongside it in [`Backend::admin`].
#[derive(Debug, Clone, PartialEq)]
pub enum AdminCmd {
    /// Reprogram the row with global id `row` to `word`.
    Update { row: u64, word: BitVec },
    /// Insert `word` as a new row (placement is the backend's concern).
    Insert { word: BitVec },
    /// Delete the row with global id `row`.
    Delete { row: u64 },
}

/// One epoch-consistent slice of a store's programmed words, as pulled by
/// a joining replica (one [`Backend::snapshot_chunk`] round trip). The
/// words are post-write-verify — exactly what the primary serves — so a
/// replica rebuilding from them is bit-exact without re-running the
/// stochastic programming model.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotChunk {
    /// Store epoch the cut was taken at. Every chunk of one stream must
    /// carry the same epoch (enforced by the request pin).
    pub epoch: u64,
    /// Total rows in the store at the cut.
    pub total_rows: u64,
    /// Word width in bits.
    pub dims: u64,
    /// Oldest epoch the server's catch-up log can still replay *from*: a
    /// replica finishing this snapshot must start its catch-up pulls at an
    /// epoch `>= log_floor` or restart.
    pub log_floor: u64,
    /// First row of this chunk.
    pub start_row: u64,
    /// The chunk's programmed words, `start_row` first. Empty when
    /// `start_row >= total_rows` (the stream is complete).
    pub rows: Vec<BitVec>,
}

/// One committed admin op in the catch-up log. `cmd` carries the
/// *programmed* word (post write-verify) so replay commits the primary's
/// exact bits instead of re-programming with a different RNG stream.
#[derive(Debug, Clone, PartialEq)]
pub struct CatchupEntry {
    /// Store epoch this op committed at (each commit bumps the epoch by 1).
    pub epoch: u64,
    /// The op, addressed in the owning store's global row ids.
    pub cmd: AdminCmd,
}

/// A catch-up log pull: every retained op after the requested epoch, plus
/// the serving epoch so the replica knows when it has fully caught up.
#[derive(Debug, Clone, PartialEq)]
pub struct CatchupBatch {
    /// The store's serving epoch at pull time; a replica is caught up when
    /// its own epoch reaches this.
    pub serving_epoch: u64,
    /// Retained ops with `epoch > from_epoch`, oldest first.
    pub entries: Vec<CatchupEntry>,
}

/// Outcome of a committed [`AdminCmd`].
#[derive(Debug, Clone, PartialEq)]
pub struct AdminOutcome {
    /// Global id of the affected row (for Insert: the new row).
    pub row: u64,
    /// Aggregate epoch (sum over shards) after the commit. Best-effort on
    /// a router: an unreachable shard contributes 0, so this can move
    /// backwards across failures — treat it as a progress hint and pin
    /// [`AdminOutcome::shard_epoch`] (exact, from the commit itself) for
    /// CAS retries.
    pub epoch: u64,
    /// The **owning shard's** epoch after the commit — the value to pin as
    /// `expected_epoch` on the next CAS retry against the same row.
    pub shard_epoch: u64,
    /// Total stored rows after the commit.
    pub rows: u64,
    /// Write-verify cost (None for Delete, which spends no pulses).
    pub write: Option<WriteCost>,
}

/// Backend-specific completion state behind a [`Ticket`]. Implementations
/// must make [`Completion::poll`] nonblocking and cheap — it sits in the
/// event-loop hot path.
pub trait Completion: Send {
    /// Nonblocking readiness check. Returns `Ok(Some(_))` exactly once;
    /// the ticket is spent afterwards.
    fn poll(&mut self) -> Result<Option<BatchResult>, SubmitError>;

    /// Block until the batch completes. The default spins on
    /// [`Completion::poll`] with a short sleep; implementations with a
    /// genuinely blocking primitive (e.g. an mpsc receiver) override it.
    fn wait(&mut self) -> Result<BatchResult, SubmitError> {
        loop {
            if let Some(result) = self.poll()? {
                return Ok(result);
            }
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

/// Handle to an in-flight search batch (see the module docs for the
/// single-shot discipline).
pub struct Ticket(Box<dyn Completion>);

impl Ticket {
    /// Wrap backend-specific completion state.
    pub fn new(completion: Box<dyn Completion>) -> Ticket {
        Ticket(completion)
    }

    /// Nonblocking: `Ok(Some(result))` when the batch has finished,
    /// `Ok(None)` while it is still in flight.
    pub fn poll(&mut self) -> Result<Option<BatchResult>, SubmitError> {
        self.0.poll()
    }

    /// Block until the batch finishes — the adapter that keeps the legacy
    /// blocking call sites working on top of the completion model.
    pub fn wait(mut self) -> Result<BatchResult, SubmitError> {
        self.0.wait()
    }
}

/// One transport-agnostic, completion-based serving surface (module docs).
pub trait Backend: Send + Sync {
    /// Stored word length in bits; queries must match.
    fn dims(&self) -> usize;

    /// Hand a whole search batch to the backend without blocking. The
    /// returned [`Ticket`] completes with one ranked hit list per query,
    /// in submission order. Fails fast on malformed queries, policy
    /// violations and backpressure ([`SubmitError::Busy`]).
    fn submit_search(&self, queries: &[BitVec], k: usize) -> Result<Ticket, SubmitError>;

    /// Hand a whole **threshold** batch to the backend without blocking:
    /// each query completes with every row scoring `>= threshold` in the
    /// engine metric, rank-ordered and capped at `limit`. A cap spill is
    /// reported per query through [`BatchResult::truncated`] — the entries
    /// kept are still the best `limit`, so the flag marks incompleteness,
    /// not wrongness. Backends over single-winner substrates reject with
    /// [`SubmitError::BadQuery`].
    fn submit_threshold(
        &self,
        queries: &[BitVec],
        threshold: f64,
        limit: usize,
    ) -> Result<Ticket, SubmitError>;

    /// Apply an admin mutation, optionally pinned to an expected owning-
    /// shard epoch (compare-and-swap: a concurrent commit in between
    /// rejects with [`SubmitError::EpochMismatch`], store unchanged).
    fn admin(
        &self,
        cmd: AdminCmd,
        expected_epoch: Option<u64>,
    ) -> Result<AdminOutcome, SubmitError>;

    /// Identity + self-describing serving policy (batching hints).
    fn health(&self) -> Result<BackendHealth, SubmitError>;

    /// Point-in-time serving metrics. Snapshots carry their latency
    /// histograms where the transport allows, so aggregation across
    /// backends merges percentiles exactly.
    fn metrics(&self) -> Result<MetricsSnapshot, SubmitError>;

    /// Pull one epoch-consistent slice of the store's programmed words
    /// (replication, v4). `pin = None` on the first chunk learns the cut
    /// epoch; later chunks pin it, and a store that moved in between
    /// rejects with [`SubmitError::EpochMismatch`] — restart from row 0.
    /// Backends that cannot serve snapshots (e.g. routers, whose children
    /// each own their rows) reject with [`SubmitError::BadQuery`].
    fn snapshot_chunk(
        &self,
        pin: Option<u64>,
        start_row: u64,
        max_rows: u64,
    ) -> Result<SnapshotChunk, SubmitError> {
        let _ = (pin, start_row, max_rows);
        Err(SubmitError::BadQuery("backend does not serve snapshots".into()))
    }

    /// Pull the retained catch-up log after `from_epoch` (replication,
    /// v4). A pull below the log floor rejects with
    /// [`SubmitError::LogTruncated`] — restart from a full snapshot.
    fn catchup(&self, from_epoch: u64) -> Result<CatchupBatch, SubmitError> {
        let _ = from_epoch;
        Err(SubmitError::BadQuery("backend does not serve the catch-up log".into()))
    }

    /// Stop accepting submissions. In-flight work drains asynchronously;
    /// the call does not block on it.
    fn close(&self);

    /// Convenience: submit and block for the result.
    fn search_batch(&self, queries: &[BitVec], k: usize) -> Result<BatchResult, SubmitError> {
        self.submit_search(queries, k)?.wait()
    }

    /// Convenience: submit a threshold batch and block for the result.
    fn search_threshold_batch(
        &self,
        queries: &[BitVec],
        threshold: f64,
        limit: usize,
    ) -> Result<BatchResult, SubmitError> {
        self.submit_threshold(queries, threshold, limit)?.wait()
    }
}

/// [`Backend`] delegation through [`Arc`], so one backend can serve both a
/// request path that owns a `Box<dyn Backend>` and a long-lived helper
/// thread (e.g. the router's health probe) holding its own handle. Every
/// method — including the default-provided replication and convenience
/// wrappers — forwards to the shared backend, so wrapping never changes
/// behavior.
impl<B: Backend + ?Sized> Backend for Arc<B> {
    fn dims(&self) -> usize {
        (**self).dims()
    }
    fn submit_search(&self, queries: &[BitVec], k: usize) -> Result<Ticket, SubmitError> {
        (**self).submit_search(queries, k)
    }
    fn submit_threshold(
        &self,
        queries: &[BitVec],
        threshold: f64,
        limit: usize,
    ) -> Result<Ticket, SubmitError> {
        (**self).submit_threshold(queries, threshold, limit)
    }
    fn admin(
        &self,
        cmd: AdminCmd,
        expected_epoch: Option<u64>,
    ) -> Result<AdminOutcome, SubmitError> {
        (**self).admin(cmd, expected_epoch)
    }
    fn health(&self) -> Result<BackendHealth, SubmitError> {
        (**self).health()
    }
    fn metrics(&self) -> Result<MetricsSnapshot, SubmitError> {
        (**self).metrics()
    }
    fn snapshot_chunk(
        &self,
        pin: Option<u64>,
        start_row: u64,
        max_rows: u64,
    ) -> Result<SnapshotChunk, SubmitError> {
        (**self).snapshot_chunk(pin, start_row, max_rows)
    }
    fn catchup(&self, from_epoch: u64) -> Result<CatchupBatch, SubmitError> {
        (**self).catchup(from_epoch)
    }
    fn close(&self) {
        (**self).close()
    }
    fn search_batch(&self, queries: &[BitVec], k: usize) -> Result<BatchResult, SubmitError> {
        (**self).search_batch(queries, k)
    }
    fn search_threshold_batch(
        &self,
        queries: &[BitVec],
        threshold: f64,
        limit: usize,
    ) -> Result<BatchResult, SubmitError> {
        (**self).search_threshold_batch(queries, threshold, limit)
    }
}

// ---------------------------------------------------------------------------
// LocalBackend
// ---------------------------------------------------------------------------

/// [`Backend`] over an in-process [`AmService`]: the completion is the
/// service's existing per-request mpsc receiver, polled with `try_recv`.
/// Global row ids equal local row indices (a flat, single-shard store).
pub struct LocalBackend {
    svc: AmService,
}

impl LocalBackend {
    /// Wrap a running service as an in-process backend.
    pub fn new(svc: AmService) -> LocalBackend {
        LocalBackend { svc }
    }

    /// The wrapped service (for epoch/metrics inspection and snapshots).
    pub fn service(&self) -> &AmService {
        &self.svc
    }
}

/// Completion over the service's per-query reply channels. Each slot
/// collects the query's hit list plus its threshold truncation flag.
struct LocalCompletion {
    rxs: Vec<mpsc::Receiver<SearchResponse>>,
    collected: Vec<Option<(Vec<Hit>, bool)>>,
    epoch: u64,
}

fn hits_of(resp: &SearchResponse) -> Vec<Hit> {
    resp.hits.iter().map(|h| Hit { row: h.winner as u64, score: h.score }).collect()
}

impl LocalCompletion {
    fn take_results(&mut self) -> BatchResult {
        let mut results = Vec::with_capacity(self.collected.len());
        let mut truncated = Vec::with_capacity(self.collected.len());
        for c in self.collected.iter_mut() {
            let (hits, trunc) = c.take().unwrap_or_default();
            results.push(hits);
            truncated.push(trunc);
        }
        BatchResult { epoch: self.epoch, results, truncated, partial: false }
    }
}

impl Completion for LocalCompletion {
    fn poll(&mut self) -> Result<Option<BatchResult>, SubmitError> {
        let mut done = true;
        for (i, rx) in self.rxs.iter().enumerate() {
            if self.collected[i].is_some() {
                continue;
            }
            match rx.try_recv() {
                Ok(resp) => {
                    self.epoch = self.epoch.max(resp.epoch);
                    self.collected[i] = Some((hits_of(&resp), resp.truncated));
                }
                Err(mpsc::TryRecvError::Empty) => done = false,
                Err(mpsc::TryRecvError::Disconnected) => return Err(SubmitError::Closed),
            }
        }
        if !done {
            return Ok(None);
        }
        Ok(Some(self.take_results()))
    }

    fn wait(&mut self) -> Result<BatchResult, SubmitError> {
        for (i, rx) in self.rxs.iter().enumerate() {
            if self.collected[i].is_some() {
                continue;
            }
            let resp = rx.recv().map_err(|_| SubmitError::Closed)?;
            self.epoch = self.epoch.max(resp.epoch);
            self.collected[i] = Some((hits_of(&resp), resp.truncated));
        }
        Ok(self.take_results())
    }
}

/// Convert a global row id to this flat store's local index.
fn local_row(row: u64) -> Result<usize, SubmitError> {
    usize::try_from(row).map_err(|_| {
        SubmitError::BadQuery(format!("row id {row:#x} does not fit this platform's usize"))
    })
}

impl Backend for LocalBackend {
    fn dims(&self) -> usize {
        self.svc.dims()
    }

    fn submit_search(&self, queries: &[BitVec], k: usize) -> Result<Ticket, SubmitError> {
        let mut rxs = Vec::with_capacity(queries.len());
        for q in queries {
            rxs.push(self.svc.submit_topk(q.clone(), k)?);
        }
        let collected = (0..rxs.len()).map(|_| None).collect();
        Ok(Ticket::new(Box::new(LocalCompletion { rxs, collected, epoch: 0 })))
    }

    fn submit_threshold(
        &self,
        queries: &[BitVec],
        threshold: f64,
        limit: usize,
    ) -> Result<Ticket, SubmitError> {
        let mut rxs = Vec::with_capacity(queries.len());
        for q in queries {
            rxs.push(self.svc.submit_threshold(q.clone(), threshold, limit)?);
        }
        let collected = (0..rxs.len()).map(|_| None).collect();
        Ok(Ticket::new(Box::new(LocalCompletion { rxs, collected, epoch: 0 })))
    }

    fn admin(
        &self,
        cmd: AdminCmd,
        expected_epoch: Option<u64>,
    ) -> Result<AdminOutcome, SubmitError> {
        let op = match cmd {
            AdminCmd::Update { row, word } => AdminOp::Update { row: local_row(row)?, word },
            AdminCmd::Insert { word } => AdminOp::Insert { word },
            AdminCmd::Delete { row } => AdminOp::Delete { row: local_row(row)? },
        };
        let resp = self.svc.admin_cas(op, expected_epoch)?;
        Ok(AdminOutcome {
            row: resp.row as u64,
            epoch: resp.epoch,
            shard_epoch: resp.epoch,
            rows: resp.rows as u64,
            write: resp.write.as_ref().map(WriteCost::from_report),
        })
    }

    fn health(&self) -> Result<BackendHealth, SubmitError> {
        Ok(BackendHealth {
            rows: self.svc.rows() as u64,
            dims: self.svc.dims() as u64,
            epoch: self.svc.epoch(),
            shards: 1,
            max_batch: self.svc.policy().max_batch.min(u32::MAX as usize) as u32,
            max_k: self.svc.effective_max_k().min(u32::MAX as usize) as u32,
            shards_unhealthy: 0,
        })
    }

    fn metrics(&self) -> Result<MetricsSnapshot, SubmitError> {
        Ok(self.svc.metrics())
    }

    fn snapshot_chunk(
        &self,
        pin: Option<u64>,
        start_row: u64,
        max_rows: u64,
    ) -> Result<SnapshotChunk, SubmitError> {
        self.svc.snapshot_chunk(pin, start_row, max_rows)
    }

    fn catchup(&self, from_epoch: u64) -> Result<CatchupBatch, SubmitError> {
        self.svc.catchup(from_epoch)
    }

    fn close(&self) {
        // Closing is idempotent and non-joining: the cloned handle marks
        // the service closed and lets workers drain asynchronously.
        self.svc.clone().shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::{AmEngine, DigitalExactEngine};
    use crate::config::CoordinatorConfig;
    use crate::coordinator::TileManager;
    use crate::util::rng;

    fn local(rows: usize, dims: usize) -> (LocalBackend, Vec<BitVec>) {
        let mut r = rng(19);
        let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
        let tiles = TileManager::build(words.clone(), 32, |w| {
            Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(DigitalExactEngine::new(w)))
        })
        .unwrap();
        (LocalBackend::new(AmService::start(&CoordinatorConfig::default(), tiles)), words)
    }

    #[test]
    fn submit_poll_completes_with_correct_results() {
        let (backend, words) = local(50, 64);
        let reference = DigitalExactEngine::new(words);
        let mut r = rng(20);
        let queries: Vec<BitVec> = (0..7).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let mut ticket = backend.submit_search(&queries, 3).unwrap();
        // Poll (nonblocking) until completion; must terminate.
        let result = loop {
            if let Some(done) = ticket.poll().unwrap() {
                break done;
            }
            std::thread::sleep(Duration::from_micros(20));
        };
        assert_eq!(result.results.len(), 7);
        for (q, hits) in queries.iter().zip(&result.results) {
            let want = reference.search_topk(q, 3);
            assert_eq!(hits.len(), want.len());
            for (got, exp) in hits.iter().zip(&want) {
                assert_eq!(got.row as usize, exp.winner);
                assert_eq!(got.score, exp.score);
            }
        }
        backend.close();
    }

    #[test]
    fn wait_blocks_and_empty_batches_complete_immediately() {
        let (backend, words) = local(30, 64);
        let reference = DigitalExactEngine::new(words);
        let mut r = rng(21);
        let q = BitVec::random(64, 0.5, &mut r);
        let result = backend.search_batch(std::slice::from_ref(&q), 2).unwrap();
        let want = reference.search_topk(&q, 2);
        assert_eq!(result.results[0].len(), want.len());
        assert_eq!(result.results[0][0].score, want[0].score);

        // Zero queries: a legal no-op batch.
        let empty = backend.search_batch(&[], 1).unwrap();
        assert!(empty.results.is_empty());
        backend.close();
    }

    #[test]
    fn health_advertises_policy_and_admin_round_trips() {
        let (backend, _) = local(20, 64);
        let h = backend.health().unwrap();
        assert_eq!(h.rows, 20);
        assert_eq!(h.dims, 64);
        assert_eq!(h.shards, 1);
        assert_eq!(h.max_batch as usize, CoordinatorConfig::default().max_batch);
        assert!(h.max_k >= 1);

        let mut r = rng(22);
        let w = BitVec::random(64, 0.5, &mut r);
        let out = backend.admin(AdminCmd::Insert { word: w.clone() }, None).unwrap();
        assert_eq!(out.rows, 21);
        assert_eq!(out.epoch, out.shard_epoch, "flat store: shard epoch == epoch");
        assert!(out.write.is_some());
        let hit = backend.search_batch(std::slice::from_ref(&w), 1).unwrap();
        assert_eq!(hit.results[0][0].row, out.row);

        // Stale CAS pin is a typed mismatch.
        match backend.admin(AdminCmd::Delete { row: out.row }, Some(out.shard_epoch + 7)) {
            Err(SubmitError::EpochMismatch { actual, .. }) => {
                assert_eq!(actual, out.shard_epoch)
            }
            other => panic!("expected EpochMismatch, got {other:?}"),
        }
        // Matching pin commits.
        let del = backend.admin(AdminCmd::Delete { row: out.row }, Some(out.shard_epoch)).unwrap();
        assert_eq!(del.rows, 20);
        backend.close();
    }

    #[test]
    fn threshold_batches_match_flat_reference_and_flag_truncation() {
        let (backend, words) = local(60, 64);
        let reference = DigitalExactEngine::new(words);
        let mut r = rng(23);
        let queries: Vec<BitVec> = (0..5).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let d = 36.0;
        let result = backend.search_threshold_batch(&queries, d, 64).unwrap();
        assert_eq!(result.results.len(), 5);
        assert_eq!(result.truncated.len(), 5);
        for (i, q) in queries.iter().enumerate() {
            let want = reference.search_matches(q, d, 64);
            assert_eq!(result.results[i].len(), want.len());
            for (got, exp) in result.results[i].iter().zip(want.as_slice()) {
                assert_eq!(got.row as usize, exp.winner);
                assert_eq!(got.score, exp.score);
            }
            assert_eq!(result.truncated[i], want.truncated());
        }

        // A limit of 1 under an accept-everything threshold must keep the
        // single best row and raise the per-query spill flag.
        let tight = backend.search_threshold_batch(&queries[..1], f64::MIN, 1).unwrap();
        assert_eq!(tight.results[0].len(), 1);
        assert!(tight.truncated[0]);
        let best = reference.search_topk(&queries[0], 1);
        assert_eq!(tight.results[0][0].row as usize, best[0].winner);
        backend.close();
    }

    #[test]
    fn close_rejects_further_submissions() {
        let (backend, _) = local(10, 32);
        backend.close();
        match backend.submit_search(&[BitVec::zeros(32)], 1) {
            Err(SubmitError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }
}
