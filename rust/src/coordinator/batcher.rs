//! Dynamic batcher: a bounded queue with a size-or-deadline release policy.
//!
//! Producers push pending requests (non-blocking; `Busy` when the bounded
//! depth is hit — explicit backpressure instead of unbounded latency).
//! Worker threads call [`Batcher::next_batch`], which blocks for the first
//! request and then waits at most `max_wait` for batch-mates, up to
//! `max_batch` — the standard dynamic-batching policy of serving systems.

use std::collections::VecDeque;
use std::sync::Condvar;
use std::time::{Duration, Instant};

use super::request::SubmitError;
use crate::util::sync::{wait_timeout_tracked, wait_tracked, TrackedMutex, BATCHER_QUEUE};

/// A queued item: payload + enqueue timestamp.
pub struct Pending<T> {
    /// The queued payload.
    pub item: T,
    /// When the item entered the queue (queue-wait metrics).
    pub enqueued: Instant,
}

struct State<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

/// The batching queue. The submission queue is the `batcher.queue` lock
/// class in [`crate::util::sync::lock_order`].
pub struct Batcher<T> {
    queue: TrackedMutex<State<T>>,
    cv: Condvar,
    /// Largest batch the worker will drain at once.
    pub max_batch: usize,
    /// Longest a partial batch waits for more work before executing.
    pub max_wait: Duration,
    /// Queue depth that triggers `busy` backpressure.
    pub depth: usize,
}

impl<T> Batcher<T> {
    /// Queue with the given batching policy (`max_batch`, `depth` ≥ 1).
    pub fn new(max_batch: usize, max_wait: Duration, depth: usize) -> Self {
        assert!(max_batch >= 1 && depth >= 1);
        Batcher {
            queue: TrackedMutex::new(
                &BATCHER_QUEUE,
                State { queue: VecDeque::new(), closed: false },
            ),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            depth,
        }
    }

    /// Non-blocking submit with backpressure.
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.queue.lock();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.queue.len() >= self.depth {
            return Err(SubmitError::Busy);
        }
        g.queue.push_back(Pending { item, enqueued: Instant::now() });
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Current queue depth.
    pub fn len(&self) -> usize {
        self.queue.lock().queue.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking: wait for at least one item, then gather batch-mates until
    /// `max_batch` or `max_wait` elapses. Returns `None` once closed+drained.
    pub fn next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.queue.lock();
        // Wait for the first item (or shutdown).
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = wait_tracked(&self.cv, g);
        }
        // Gather batch-mates. max_wait == 0 is the *greedy / continuous
        // batching* policy (§Perf): take whatever is already queued and go —
        // batches form naturally while workers are busy, and no core time is
        // burned waiting. A nonzero max_wait holds the batch open up to the
        // deadline (useful when the engine has strong batch economies, e.g.
        // a fixed-batch XLA artifact).
        if !self.max_wait.is_zero() {
            let deadline = Instant::now() + self.max_wait;
            loop {
                if g.queue.len() >= self.max_batch || g.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = wait_timeout_tracked(&self.cv, g, deadline - now);
                g = guard;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(self.max_batch);
        let batch: Vec<Pending<T>> = g.queue.drain(..take).collect();
        drop(g);
        // More items may remain: wake another worker.
        self.cv.notify_one();
        Some(batch)
    }

    /// Close the queue: submits fail with `Closed`; workers drain then exit.
    pub fn close(&self) {
        self.queue.lock().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn backpressure_at_depth() {
        let b: Batcher<u32> = Batcher::new(4, Duration::from_millis(1), 2);
        assert!(b.submit(1).is_ok());
        assert!(b.submit(2).is_ok());
        assert_eq!(b.submit(3), Err(SubmitError::Busy));
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn closed_rejects_submits_and_drains() {
        let b: Batcher<u32> = Batcher::new(4, Duration::from_millis(1), 8);
        b.submit(1).unwrap();
        b.close();
        assert_eq!(b.submit(2), Err(SubmitError::Closed));
        let batch = b.next_batch().expect("drain");
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none(), "closed+empty -> None");
    }

    #[test]
    fn batch_size_capped() {
        let b: Batcher<u32> = Batcher::new(3, Duration::from_millis(1), 100);
        for i in 0..10 {
            b.submit(i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.len(), 7);
    }

    /// De-flaked: no fixed sleeps. The item is queued *before* the consumer
    /// starts, so the test cannot race on producer timing; the deadline
    /// property under test is that a partial batch (1 of max 64) is
    /// released at all instead of waiting forever for batch-mates, with a
    /// generous wall-clock ceiling that even a heavily loaded CI runner
    /// clears.
    #[test]
    fn deadline_releases_partial_batch() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(64, Duration::from_millis(10), 100));
        b.submit(42).unwrap();
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let start = Instant::now();
            let batch = b2.next_batch().unwrap();
            (batch.len(), start.elapsed())
        });
        let (len, took) = t.join().unwrap();
        assert_eq!(len, 1, "deadline must release the partial batch");
        assert!(took < Duration::from_secs(30), "released by deadline, not hang: {took:?}");

        // Consumer-first order as well: the consumer blocks for the first
        // item, then the deadline releases it without 63 batch-mates.
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch().unwrap().len());
        b.submit(43).unwrap();
        assert_eq!(t.join().unwrap(), 1);
    }

    /// De-flaked: instead of sleeping a fixed 300 ms and hoping producers
    /// finished, join every producer first and only then close the queue —
    /// consumers drain the remainder and exit, however slow the runner.
    #[test]
    fn no_items_lost_under_concurrency() {
        let b: Arc<Batcher<u64>> = Arc::new(Batcher::new(8, Duration::from_micros(200), 100_000));
        let n_producers = 4;
        let per_producer = 500u64;
        let collected = std::sync::Mutex::new(Vec::<u64>::new());
        std::thread::scope(|s| {
            let producers: Vec<_> = (0..n_producers)
                .map(|p| {
                    let b = b.clone();
                    s.spawn(move || {
                        for i in 0..per_producer {
                            loop {
                                match b.submit(p * per_producer + i) {
                                    Ok(()) => break,
                                    Err(SubmitError::Busy) => std::thread::yield_now(),
                                    Err(e) => panic!("{e}"),
                                }
                            }
                        }
                    })
                })
                .collect();
            let consumers: Vec<_> = (0..2)
                .map(|_| {
                    let b = b.clone();
                    let collected = &collected;
                    s.spawn(move || {
                        while let Some(batch) = b.next_batch() {
                            let mut g = collected.lock().unwrap();
                            g.extend(batch.into_iter().map(|p| p.item));
                        }
                    })
                })
                .collect();
            // Close only after every producer has submitted everything.
            for p in producers {
                p.join().unwrap();
            }
            b.close();
            for c in consumers {
                c.join().unwrap();
            }
        });
        let mut got = collected.into_inner().unwrap();
        got.sort_unstable();
        let want: Vec<u64> = (0..n_producers * per_producer).collect();
        assert_eq!(got, want, "every submitted item consumed exactly once");
    }
}
