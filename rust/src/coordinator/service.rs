//! The AM serving service: worker threads drain the dynamic batcher into
//! the tile manager; responses flow back over per-request channels with
//! queue/execute timing attached.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::CoordinatorConfig;
use crate::util::BitVec;

use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{RequestTiming, SearchResponse, SubmitError};
use super::tiles::TileManager;

struct Job {
    query: BitVec,
    reply: mpsc::SyncSender<SearchResponse>,
}

struct Shared {
    batcher: Batcher<Job>,
    tiles: TileManager,
    metrics: Metrics,
    running: AtomicBool,
}

/// Handle to a running AM service. Cloneable; dropping all clones does NOT
/// stop the service — call [`AmService::shutdown`].
#[derive(Clone)]
pub struct AmService {
    shared: Arc<Shared>,
    workers: Arc<Vec<std::thread::JoinHandle<()>>>,
}

impl AmService {
    /// Start `cfg.workers` worker threads over a tile manager.
    pub fn start(cfg: &CoordinatorConfig, tiles: TileManager) -> AmService {
        let shared = Arc::new(Shared {
            batcher: Batcher::new(
                cfg.max_batch,
                Duration::from_micros(cfg.max_wait_us),
                cfg.queue_depth,
            ),
            tiles,
            metrics: Metrics::new(),
            running: AtomicBool::new(true),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cosime-worker-{w}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn worker")
            })
            .collect();
        AmService { shared, workers: Arc::new(workers) }
    }

    /// Submit a query; returns a receiver for the response.
    /// Fails fast with `Busy` under backpressure.
    pub fn submit(&self, query: BitVec) -> Result<mpsc::Receiver<SearchResponse>, SubmitError> {
        if query.len() != self.shared.tiles.dims() {
            return Err(SubmitError::BadQuery(format!(
                "query has {} bits, engine expects {}",
                query.len(),
                self.shared.tiles.dims()
            )));
        }
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let (reply, rx) = mpsc::sync_channel(1);
        self.shared.metrics.on_submit();
        match self.shared.batcher.submit(Job { query, reply }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                if e == SubmitError::Busy {
                    self.shared.metrics.on_reject_busy();
                }
                Err(e)
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn search_blocking(&self, query: BitVec) -> Result<SearchResponse, SubmitError> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Submit with bounded retries under backpressure.
    pub fn search_with_retry(
        &self,
        query: BitVec,
        max_retries: usize,
    ) -> Result<SearchResponse, SubmitError> {
        let mut tries = 0;
        loop {
            match self.search_blocking(query.clone()) {
                Err(SubmitError::Busy) if tries < max_retries => {
                    tries += 1;
                    std::thread::sleep(Duration::from_micros(50 << tries.min(6)));
                }
                other => return other,
            }
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn rows(&self) -> usize {
        self.shared.tiles.rows()
    }

    pub fn dims(&self) -> usize {
        self.shared.tiles.dims()
    }

    pub fn queue_len(&self) -> usize {
        self.shared.batcher.len()
    }

    /// Graceful shutdown: drain the queue, stop workers, join them.
    pub fn shutdown(self) {
        self.shared.running.store(false, Ordering::Release);
        self.shared.batcher.close();
        if let Ok(workers) = Arc::try_unwrap(self.workers) {
            for w in workers {
                let _ = w.join();
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    while let Some(batch) = shared.batcher.next_batch() {
        let now = Instant::now();
        shared.metrics.on_batch(batch.len());
        let queries: Vec<BitVec> = batch.iter().map(|p| p.item.query.clone()).collect();
        let results = shared.tiles.search_batch(&queries);
        let exec = now.elapsed();
        for (pending, result) in batch.into_iter().zip(results) {
            let queued = now.duration_since(pending.enqueued);
            shared.metrics.on_complete(queued, exec);
            let timing = RequestTiming { queued, exec, batch_size: queries.len() };
            let _ = pending.item.reply.send(SearchResponse {
                winner: result.winner,
                score: result.score,
                timing,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::{AmEngine, DigitalExactEngine};
    use crate::util::rng;

    fn service(rows: usize, dims: usize, cfg: &CoordinatorConfig) -> (AmService, Vec<BitVec>) {
        let mut r = rng(7);
        let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
        let tiles = TileManager::build(words.clone(), 64, |w| {
            Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(DigitalExactEngine::new(w)))
        })
        .unwrap();
        (AmService::start(cfg, tiles), words)
    }

    #[test]
    fn serves_correct_results() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(100, 64, &cfg);
        let reference = DigitalExactEngine::new(words.clone());
        let mut r = rng(8);
        for _ in 0..30 {
            let q = BitVec::random(64, 0.5, &mut r);
            let resp = svc.search_blocking(q.clone()).unwrap();
            assert_eq!(resp.winner, reference.search(&q).winner);
            assert!(resp.timing.batch_size >= 1);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 30);
        svc.shutdown();
    }

    #[test]
    fn self_queries_return_self() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(50, 64, &cfg);
        for (i, w) in words.iter().enumerate().take(10) {
            let resp = svc.search_blocking(w.clone()).unwrap();
            assert_eq!(resp.winner, i);
        }
        svc.shutdown();
    }

    #[test]
    fn bad_query_rejected_immediately() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        match svc.submit(BitVec::zeros(32)) {
            Err(SubmitError::BadQuery(_)) => {}
            other => panic!("expected BadQuery, got {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn closed_after_shutdown() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        let svc2 = svc.clone();
        svc.shutdown();
        assert!(matches!(svc2.submit(BitVec::zeros(64)), Err(SubmitError::Closed)));
    }

    #[test]
    fn concurrent_clients_all_served() {
        let cfg = CoordinatorConfig { max_batch: 16, max_wait_us: 100, queue_depth: 1024, workers: 3 };
        let (svc, words) = service(200, 64, &cfg);
        let reference = DigitalExactEngine::new(words);
        let errors = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..6 {
                let svc = svc.clone();
                let reference = &reference;
                let errors = &errors;
                s.spawn(move || {
                    let mut r = rng(50 + t);
                    for _ in 0..50 {
                        let q = BitVec::random(64, 0.5, &mut r);
                        match svc.search_with_retry(q.clone(), 10) {
                            Ok(resp) => {
                                if resp.winner != reference.search(&q).winner {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(errors.load(Ordering::Relaxed), 0);
        let m = svc.metrics();
        assert_eq!(m.completed, 300);
        assert!(m.mean_batch_size >= 1.0);
        svc.shutdown();
    }

    #[test]
    fn backpressure_under_tiny_queue() {
        // One slow worker + depth 1: bursts must hit Busy, not hang.
        let cfg = CoordinatorConfig { max_batch: 1, max_wait_us: 1, queue_depth: 1, workers: 1 };
        let (svc, _) = service(2000, 256, &cfg);
        let mut r = rng(9);
        let mut busy = 0;
        let mut rxs = Vec::new();
        for _ in 0..200 {
            match svc.submit(BitVec::random(256, 0.5, &mut r)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Busy) => busy += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(busy > 0, "tiny queue must reject some of a 200-burst");
        for rx in rxs {
            let _ = rx.recv();
        }
        assert_eq!(svc.metrics().rejected_busy as usize, busy);
        svc.shutdown();
    }
}
