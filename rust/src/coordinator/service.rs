//! The AM serving service: worker threads drain the dynamic batcher into
//! the tile manager's batched top-k kernel; responses flow back over
//! per-request channels with queue/execute timing attached.
//!
//! Each worker owns one [`QueryBlock`] per query kind, one
//! [`TileScratch`](super::tiles::TileScratch), one [`BlockTopK`] and one
//! [`BlockMatches`] for its whole lifetime, so the steady-state loop
//! performs zero per-query heap allocations on the scoring side: queries
//! are packed straight from the queued jobs into the reused blocks (a mixed
//! batch is partitioned by [`QueryKind`]), scored through the tile×batch
//! kernel, and only the per-response `hits` vector (the data handed back
//! across the channel) is allocated.
//!
//! Alongside the search plane sits the *admin plane*
//! ([`AmService::admin`]): live class-vector updates. An Update/Insert word
//! first passes through the §4 ±4 V write-verify programming model (so the
//! store serves what the array would actually read back, and the response
//! carries the pulse-accurate write cost), then commits to the tile manager
//! under its epoch lock. In-flight batches keep scoring the old snapshot;
//! every response is stamped with the epoch it was served at.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::am::store::program_word_verified;
use crate::am::write::WriteReport;
use crate::am::{BlockMatches, BlockTopK, QueryBlock, QueryKind, SearchResult};
use crate::config::{CoordinatorConfig, CosimeConfig};
use crate::util::sync::{TrackedMutex, SERVICE_LOG, SERVICE_WRITER};
use crate::util::{BitVec, Rng};

use super::backend::{AdminCmd, CatchupBatch, CatchupEntry, SnapshotChunk};
use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{AdminOp, AdminResponse, RequestTiming, SearchResponse, SubmitError};
use super::tiles::TileManager;

struct Job {
    query: BitVec,
    /// What the query asks for: ranked top-k or a bounded threshold scan.
    kind: QueryKind,
    /// Response-size bound: `k` for top-k (mirrors the kind), the match-set
    /// `limit` for threshold.
    limit: usize,
    reply: mpsc::SyncSender<SearchResponse>,
}

/// The admin plane's programming model: device/energy config (including
/// the `[write]` policy) and the cycle-to-cycle stochasticity stream. One
/// mutex serializes programming passes (a real array has one write port).
struct WritePath {
    cfg: CosimeConfig,
    rng: Rng,
}

/// Bounded catch-up log of committed admin ops — the replication feed.
///
/// Entries carry the *programmed* word exactly as it was committed on this
/// node (post write-verify), so replaying an entry on a replica is bit-exact
/// by construction: the replica commits the carried bits directly and never
/// re-runs the stochastic programming model. Entries are kept oldest-first
/// with strictly increasing epochs; eviction advances `floor`.
struct ReplLog {
    entries: VecDeque<CatchupEntry>,
    /// Oldest epoch a catch-up pull can start *from*: a pull with
    /// `from_epoch >= floor` can be served entirely from `entries`; below it
    /// the requested history is gone and the puller must take a snapshot.
    floor: u64,
    capacity: usize,
}

impl ReplLog {
    /// Insert a committed entry, keeping epoch order. Commits serialize
    /// under the tile write lock but pushes happen after it is released, so
    /// two writers can arrive here out of order — walk back from the tail
    /// (in practice this is a straight append).
    fn push(&mut self, entry: CatchupEntry) {
        let mut i = self.entries.len();
        while i > 0 && self.entries[i - 1].epoch > entry.epoch {
            i -= 1;
        }
        self.entries.insert(i, entry);
        while self.entries.len() > self.capacity {
            if let Some(evicted) = self.entries.pop_front() {
                self.floor = self.floor.max(evicted.epoch);
            }
        }
    }
}

struct Shared {
    batcher: Batcher<Job>,
    tiles: TileManager,
    metrics: Metrics,
    running: AtomicBool,
    /// Policy cap on requested k ([`CoordinatorConfig::max_k`]): the whole
    /// batch is scored at its deepest k, so one unbounded request would tax
    /// every co-batched query.
    max_k_policy: usize,
    /// Policy cap on a threshold query's match-set `limit`
    /// ([`CoordinatorConfig::max_matches`]): a threshold selector costs
    /// O(limit) maintenance per qualifying row, so unbounded requests would
    /// tax the batch the same way deep k does.
    max_matches_policy: usize,
    /// The serving policy this service was started with — read-only after
    /// start; exposed so frontends can advertise `max_batch`/`max_k` to
    /// clients (wire-level batching hints).
    policy: CoordinatorConfig,
    /// Write-verify loop state: the `service.writer` lock class, held for
    /// the whole programming pass (outermost in
    /// [`crate::util::sync::lock_order`]).
    writer: TrackedMutex<WritePath>,
    /// Replication feed: committed admin ops with their programmed words,
    /// bounded by `[replication] log_capacity` — the `service.log` class.
    log: TrackedMutex<ReplLog>,
    /// Server-side cap on one snapshot chunk's row count
    /// (`[replication] snapshot_chunk_rows`); pullers asking for more get a
    /// shorter chunk and advance by what they received.
    snapshot_chunk_rows: usize,
}

/// Handle to a running AM service. Cloneable; dropping all clones does NOT
/// stop the service — call [`AmService::shutdown`].
#[derive(Clone)]
pub struct AmService {
    shared: Arc<Shared>,
    workers: Arc<Vec<std::thread::JoinHandle<()>>>,
}

impl AmService {
    /// Start `cfg.workers` worker threads over a tile manager. The admin
    /// plane's programming model uses default physical parameters; use
    /// [`AmService::start_with_config`] to supply a full [`CosimeConfig`].
    pub fn start(cfg: &CoordinatorConfig, tiles: TileManager) -> AmService {
        let mut full = CosimeConfig::default();
        full.coordinator = cfg.clone();
        Self::start_with_config(&full, tiles)
    }

    /// Start the service with a full configuration: `cfg.coordinator` sets
    /// the serving policy, `cfg.device`/`cfg.energy` the admin plane's
    /// programming model and `cfg.write` its pulse/retry policy.
    pub fn start_with_config(full: &CosimeConfig, tiles: TileManager) -> AmService {
        let cfg = &full.coordinator;
        // A replica seeds its tile epoch to the snapshot cut *before*
        // starting the service, so the log's floor starts at the cut: the
        // history below it was never seen here and cannot be replayed.
        let log_floor = tiles.epoch();
        let shared = Arc::new(Shared {
            batcher: Batcher::new(
                cfg.max_batch,
                Duration::from_micros(cfg.max_wait_us),
                cfg.queue_depth,
            ),
            tiles,
            metrics: Metrics::new(),
            running: AtomicBool::new(true),
            max_k_policy: cfg.max_k.max(1),
            max_matches_policy: cfg.max_matches.max(1),
            policy: cfg.clone(),
            writer: TrackedMutex::new(
                &SERVICE_WRITER,
                WritePath { cfg: full.clone(), rng: Rng::seed_from_u64(full.write.seed) },
            ),
            log: TrackedMutex::new(
                &SERVICE_LOG,
                ReplLog {
                    entries: VecDeque::new(),
                    floor: log_floor,
                    capacity: full.replication.log_capacity.max(1),
                },
            ),
            snapshot_chunk_rows: full.replication.snapshot_chunk_rows.max(1),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cosime-worker-{w}"))
                    .spawn(move || worker_loop(&shared))
                    // lint: allow(no-panic) -- startup-time: a service that
                    // cannot spawn its workers cannot serve at all, and this
                    // runs before any request is accepted.
                    .expect("spawn worker")
            })
            .collect();
        AmService { shared, workers: Arc::new(workers) }
    }

    /// Submit a single-winner query (k = 1); returns a receiver for the
    /// response. Fails fast with `Busy` under backpressure.
    pub fn submit(&self, query: BitVec) -> Result<mpsc::Receiver<SearchResponse>, SubmitError> {
        self.submit_topk(query, 1)
    }

    /// Submit a top-k query: the response's `hits` carries the
    /// `min(k, rows)` ranked winners. Fails fast with `Busy` under
    /// backpressure.
    pub fn submit_topk(
        &self,
        query: BitVec,
        k: usize,
    ) -> Result<mpsc::Receiver<SearchResponse>, SubmitError> {
        if query.len() != self.shared.tiles.dims() {
            return Err(SubmitError::BadQuery(format!(
                "query has {} bits, engine expects {}",
                query.len(),
                self.shared.tiles.dims()
            )));
        }
        if k == 0 {
            return Err(SubmitError::BadQuery("k must be at least 1".to_string()));
        }
        let rows = self.shared.tiles.rows();
        // Policy gate: deep k taxes the whole batch (scored at the batch's
        // deepest k), so requests beyond the configured cap are rejected.
        if k.min(rows) > self.shared.max_k_policy {
            return Err(SubmitError::BadQuery(format!(
                "k={k} exceeds the service's max_k policy ({})",
                self.shared.max_k_policy
            )));
        }
        // Capability gate: a tile backed by a single-winner substrate (e.g.
        // a fixed-argmax XLA artifact) cannot serve deep k; reject here
        // rather than failing inside a worker mid-batch. `max_k` is one
        // atomic load — every admin commit refreshes it under the tile
        // write lock, so it cannot go stale under racing mutations.
        let max_k = self.shared.tiles.max_k();
        if k.min(rows) > max_k {
            return Err(SubmitError::BadQuery(format!(
                "k={k} exceeds the engine's top-k capability ({max_k})"
            )));
        }
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let (reply, rx) = mpsc::sync_channel(1);
        self.shared.metrics.on_submit();
        match self.shared.batcher.submit(Job { query, kind: QueryKind::TopK(k), limit: k, reply })
        {
            Ok(()) => Ok(rx),
            Err(e) => {
                if e == SubmitError::Busy {
                    self.shared.metrics.on_reject_busy();
                }
                Err(e)
            }
        }
    }

    /// Submit a threshold (range) query: the response's `hits` carries every
    /// row scoring `>= threshold`, rank-ordered, capped at `limit` (with the
    /// response's `truncated` flag set when the cap cut qualifying rows).
    /// Fails fast with `Busy` under backpressure; rejected up front when the
    /// engine substrate cannot rank beyond its winner (see
    /// [`AmService::supports_threshold`]).
    pub fn submit_threshold(
        &self,
        query: BitVec,
        threshold: f64,
        limit: usize,
    ) -> Result<mpsc::Receiver<SearchResponse>, SubmitError> {
        if query.len() != self.shared.tiles.dims() {
            return Err(SubmitError::BadQuery(format!(
                "query has {} bits, engine expects {}",
                query.len(),
                self.shared.tiles.dims()
            )));
        }
        if limit == 0 {
            return Err(SubmitError::BadQuery("limit must be at least 1".to_string()));
        }
        if !threshold.is_finite() {
            return Err(SubmitError::BadQuery(format!(
                "threshold must be finite, got {threshold}"
            )));
        }
        // Policy gate, mirroring max_k: a threshold selector costs O(limit)
        // insertion maintenance per qualifying row.
        if limit > self.shared.max_matches_policy {
            return Err(SubmitError::BadQuery(format!(
                "limit={limit} exceeds the service's max_matches policy ({})",
                self.shared.max_matches_policy
            )));
        }
        // Capability gate: a single-winner substrate (e.g. a fixed-argmax
        // XLA artifact) cannot enumerate a match set; reject here rather
        // than failing inside a worker mid-batch. One atomic load, refreshed
        // by every admin commit under the tile write lock.
        if !self.shared.tiles.supports_threshold() {
            return Err(SubmitError::BadQuery(
                "engine does not support threshold queries (single-winner substrate)".to_string(),
            ));
        }
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let (reply, rx) = mpsc::sync_channel(1);
        self.shared.metrics.on_submit();
        match self.shared.batcher.submit(Job {
            query,
            kind: QueryKind::Threshold(threshold),
            limit,
            reply,
        }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                if e == SubmitError::Busy {
                    self.shared.metrics.on_reject_busy();
                }
                Err(e)
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn search_blocking(&self, query: BitVec) -> Result<SearchResponse, SubmitError> {
        let rx = self.submit(query)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Convenience: submit a top-k query and block for the ranked response.
    pub fn search_topk_blocking(
        &self,
        query: BitVec,
        k: usize,
    ) -> Result<SearchResponse, SubmitError> {
        let rx = self.submit_topk(query, k)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Convenience: submit a threshold query and block for the bounded
    /// match set.
    pub fn search_threshold_blocking(
        &self,
        query: BitVec,
        threshold: f64,
        limit: usize,
    ) -> Result<SearchResponse, SubmitError> {
        let rx = self.submit_threshold(query, threshold, limit)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Submit with bounded retries under backpressure.
    pub fn search_with_retry(
        &self,
        query: BitVec,
        max_retries: usize,
    ) -> Result<SearchResponse, SubmitError> {
        self.search_topk_with_retry(query, 1, max_retries)
    }

    /// Top-k submit with bounded retries under backpressure.
    pub fn search_topk_with_retry(
        &self,
        query: BitVec,
        k: usize,
        max_retries: usize,
    ) -> Result<SearchResponse, SubmitError> {
        let mut tries = 0;
        loop {
            match self.search_topk_blocking(query.clone(), k) {
                Err(SubmitError::Busy) if tries < max_retries => {
                    tries += 1;
                    std::thread::sleep(Duration::from_micros(50 << tries.min(6)));
                }
                other => return other,
            }
        }
    }

    /// Apply a live store mutation (the admin plane). Update/Insert words
    /// are programmed through the write-verify model first — a word whose
    /// cells fail verify is rejected with [`SubmitError::WriteFailed`] and
    /// never served. Commits are epoch-ordered against in-flight batches:
    /// every search response stamped with an epoch ≥ the returned one
    /// observes this mutation.
    pub fn admin(&self, op: AdminOp) -> Result<AdminResponse, SubmitError> {
        self.admin_cas(op, None)
    }

    /// [`AmService::admin`] with an optional compare-and-swap guard: with
    /// `expected_epoch = Some(e)`, the mutation commits only if the store
    /// epoch still equals `e` at commit time (checked atomically under the
    /// tile write lock); a concurrent writer's commit in between rejects
    /// the op with [`SubmitError::EpochMismatch`] and leaves the store
    /// unchanged — the retry-safe multi-writer admin path.
    pub fn admin_cas(
        &self,
        op: AdminOp,
        expected_epoch: Option<u64>,
    ) -> Result<AdminResponse, SubmitError> {
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let kind = op.kind();
        let t0 = Instant::now();
        match self.apply_admin(op, expected_epoch) {
            Ok((row, commit, write)) => {
                self.shared.metrics.on_admin(kind, t0.elapsed(), write.as_ref());
                // rows comes from the commit itself (captured under the tile
                // write lock), so it cannot disagree with the epoch when
                // admin ops race each other.
                Ok(AdminResponse { row, epoch: commit.epoch, rows: commit.rows, write })
            }
            Err(e) => {
                self.shared.metrics.on_admin_rejected();
                Err(e)
            }
        }
    }

    /// Map a tile-manager rejection to the typed submit error: a CAS
    /// failure surfaces as [`SubmitError::EpochMismatch`], everything else
    /// as a bad query.
    fn admin_err(e: anyhow::Error) -> SubmitError {
        match e.downcast_ref::<super::tiles::EpochMismatch>() {
            Some(m) => SubmitError::EpochMismatch { expected: m.expected, actual: m.actual },
            None => SubmitError::BadQuery(format!("{e:#}")),
        }
    }

    fn apply_admin(
        &self,
        op: AdminOp,
        expected_epoch: Option<u64>,
    ) -> Result<(usize, super::tiles::Commit, Option<WriteReport>), SubmitError> {
        // Fast-fail a doomed CAS before spending programming pulses. This
        // is only an optimization — the authoritative check happens again
        // under the tile write lock at commit time.
        if let Some(expected) = expected_epoch {
            let actual = self.shared.tiles.epoch();
            if expected != actual {
                return Err(SubmitError::EpochMismatch { expected, actual });
            }
        }
        match op {
            AdminOp::Update { row, word } => {
                // Cheap bounds pre-check before spending programming pulses
                // (the tile manager re-validates under its lock).
                if row >= self.shared.tiles.rows() {
                    return Err(SubmitError::BadQuery(format!(
                        "row {row} out of range {}",
                        self.shared.tiles.rows()
                    )));
                }
                let (programmed, report) = self.program(&word)?;
                let commit = self
                    .shared
                    .tiles
                    .update_row_cas(row, &programmed, expected_epoch)
                    .map_err(Self::admin_err)?;
                self.push_log(CatchupEntry {
                    epoch: commit.epoch,
                    cmd: AdminCmd::Update { row: row as u64, word: programmed },
                });
                Ok((row, commit, Some(report)))
            }
            AdminOp::Insert { word } => {
                let (programmed, report) = self.program(&word)?;
                let (row, commit) = self
                    .shared
                    .tiles
                    .insert_row_cas(&programmed, expected_epoch)
                    .map_err(Self::admin_err)?;
                self.push_log(CatchupEntry {
                    epoch: commit.epoch,
                    cmd: AdminCmd::Insert { word: programmed },
                });
                Ok((row, commit, Some(report)))
            }
            AdminOp::Delete { row } => {
                let commit = self
                    .shared
                    .tiles
                    .delete_row_cas(row, expected_epoch)
                    .map_err(Self::admin_err)?;
                self.push_log(CatchupEntry {
                    epoch: commit.epoch,
                    cmd: AdminCmd::Delete { row: row as u64 },
                });
                Ok((row, commit, None))
            }
        }
    }

    /// Run one word through the ±4 V write-verify programming model,
    /// returning what the array reads back plus the pulse-accurate cost.
    fn program(&self, word: &BitVec) -> Result<(BitVec, WriteReport), SubmitError> {
        if word.len() != self.shared.tiles.dims() {
            return Err(SubmitError::BadQuery(format!(
                "word has {} bits, engine expects {}",
                word.len(),
                self.shared.tiles.dims()
            )));
        }
        let mut w = self.shared.writer.lock();
        let WritePath { cfg, rng } = &mut *w;
        program_word_verified(cfg, word, rng).map_err(|e| {
            // The array fired the pulses whether or not verify passed —
            // account the spent cost before rejecting the word (mirrors
            // AmStore::program's policy).
            self.shared.metrics.on_write_spent(&e.report);
            SubmitError::WriteFailed(e.to_string())
        })
    }

    /// Record a committed mutation in the replication feed.
    fn push_log(&self, entry: CatchupEntry) {
        self.shared.log.lock().push(entry);
    }

    /// Serve one epoch-consistent slice of the store for a joining replica.
    ///
    /// The slice is cut under the tile read lock, so its rows and its
    /// `epoch` stamp belong to one consistent store state. A multi-chunk
    /// pull pins the first chunk's epoch on every later request
    /// (`pin = Some(e)`): if an admin commit moved the store in between,
    /// the pull is rejected with [`SubmitError::EpochMismatch`] and the
    /// replica restarts from row 0 — chunks from different epochs never
    /// mix. Rows are the *programmed* words as served here, so a replica
    /// loading them is bit-exact. The server caps the chunk at its
    /// configured `[replication] snapshot_chunk_rows`; pullers advance by
    /// the row count actually returned.
    pub fn snapshot_chunk(
        &self,
        pin: Option<u64>,
        start_row: u64,
        max_rows: u64,
    ) -> Result<SnapshotChunk, SubmitError> {
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        if max_rows == 0 {
            return Err(SubmitError::BadQuery("snapshot chunk max_rows must be at least 1".into()));
        }
        let start = usize::try_from(start_row).map_err(|_| {
            SubmitError::BadQuery(format!(
                "snapshot start row {start_row:#x} does not fit this platform's usize"
            ))
        })?;
        let max =
            usize::try_from(max_rows).unwrap_or(usize::MAX).min(self.shared.snapshot_chunk_rows);
        let (epoch, total, rows) = self.shared.tiles.snapshot_range(start, max);
        if let Some(p) = pin {
            if p != epoch {
                return Err(SubmitError::EpochMismatch { expected: p, actual: epoch });
            }
        }
        let log_floor = self.shared.log.lock().floor;
        Ok(SnapshotChunk {
            epoch,
            total_rows: total as u64,
            dims: self.shared.tiles.dims() as u64,
            log_floor,
            start_row,
            rows,
        })
    }

    /// Serve the catch-up feed: every logged mutation with epoch
    /// `> from_epoch`, plus the serving epoch the puller should replay up
    /// to. A pull below the log's floor (the history was evicted) is
    /// rejected with [`SubmitError::LogTruncated`] carrying the floor — the
    /// puller restarts from a full snapshot. The returned `serving_epoch`
    /// is read after the entries are collected, so it is always ≥ every
    /// returned entry's epoch; an entry committed but not yet logged at
    /// collection time simply arrives on the puller's next round.
    pub fn catchup(&self, from_epoch: u64) -> Result<CatchupBatch, SubmitError> {
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let entries: Vec<CatchupEntry> = {
            let log = self.shared.log.lock();
            if from_epoch < log.floor {
                return Err(SubmitError::LogTruncated { floor: log.floor });
            }
            log.entries.iter().filter(|e| e.epoch > from_epoch).cloned().collect()
        };
        let serving_epoch = self.shared.tiles.epoch();
        Ok(CatchupBatch { serving_epoch, entries })
    }

    /// Apply one replicated catch-up entry bit-exact.
    ///
    /// The entry carries the word exactly as the primary committed it
    /// (post write-verify), so this commits the bits directly — bypassing
    /// the local programming model, which the primary already paid for —
    /// with a CAS pin of `entry.epoch - 1`: the commit lands only if this
    /// store is exactly one epoch behind the entry, which guarantees the
    /// post-commit epoch equals the entry's. Any mismatch surfaces as
    /// [`SubmitError::EpochMismatch`] — the replica's history would
    /// otherwise fork from the primary's. Applied entries re-enter the
    /// local replication feed, so a caught-up replica can serve
    /// [`AmService::catchup`] itself.
    pub fn apply_replicated(&self, entry: CatchupEntry) -> Result<(), SubmitError> {
        if !self.shared.running.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        if entry.epoch == 0 {
            return Err(SubmitError::BadQuery(
                "catch-up entry epoch 0 is not a committed mutation".into(),
            ));
        }
        let local_row = |row: u64| -> Result<usize, SubmitError> {
            usize::try_from(row).map_err(|_| {
                SubmitError::BadQuery(format!(
                    "row id {row:#x} does not fit this platform's usize"
                ))
            })
        };
        let pin = Some(entry.epoch - 1);
        match &entry.cmd {
            AdminCmd::Update { row, word } => {
                self.shared
                    .tiles
                    .update_row_cas(local_row(*row)?, word, pin)
                    .map_err(Self::admin_err)?;
            }
            AdminCmd::Insert { word } => {
                self.shared.tiles.insert_row_cas(word, pin).map_err(Self::admin_err)?;
            }
            AdminCmd::Delete { row } => {
                self.shared.tiles.delete_row_cas(local_row(*row)?, pin).map_err(Self::admin_err)?;
            }
        }
        self.push_log(entry);
        Ok(())
    }

    /// Current store epoch (bumped by every committed admin mutation).
    pub fn epoch(&self) -> u64 {
        self.shared.tiles.epoch()
    }

    /// Consistent flat copy of the stored words — feed this to
    /// [`crate::am::store::AmStore`] to persist a live server.
    pub fn snapshot_words(&self) -> Vec<BitVec> {
        self.shared.tiles.snapshot_words()
    }

    /// Point-in-time metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// The serving policy this service was started with (batching caps,
    /// queue depth, `max_k`). Frontends advertise `max_batch`/`max_k` from
    /// here so clients can self-tune instead of probing with `BadQuery`.
    pub fn policy(&self) -> &CoordinatorConfig {
        &self.shared.policy
    }

    /// The deepest k a request can currently ask for: the policy cap
    /// intersected with the engines' live capability.
    pub fn effective_max_k(&self) -> usize {
        self.shared.max_k_policy.min(self.shared.tiles.max_k())
    }

    /// Whether the live engine substrate can serve threshold queries (all
    /// tiles can enumerate their match set, not just a single winner).
    pub fn supports_threshold(&self) -> bool {
        self.shared.tiles.supports_threshold()
    }

    /// Stored row count (live; changes under admin traffic).
    pub fn rows(&self) -> usize {
        self.shared.tiles.rows()
    }

    /// Word width in bits.
    pub fn dims(&self) -> usize {
        self.shared.tiles.dims()
    }

    /// Search requests currently queued.
    pub fn queue_len(&self) -> usize {
        self.shared.batcher.len()
    }

    /// Graceful shutdown: drain the queue, stop workers, join them.
    pub fn shutdown(self) {
        self.shared.running.store(false, Ordering::Release);
        self.shared.batcher.close();
        if let Ok(workers) = Arc::try_unwrap(self.workers) {
            for w in workers {
                let _ = w.join();
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    // Worker-lifetime buffers: the whole steady-state loop reuses these.
    let mut block = QueryBlock::new(shared.tiles.dims());
    let mut tblock = QueryBlock::new(shared.tiles.dims());
    let mut scratch = shared.tiles.scratch();
    let mut out = BlockTopK::new();
    let mut matches = BlockMatches::new();
    // Per-job slot index into its kind's block (top-k and threshold queries
    // are packed into separate blocks, in arrival order).
    let mut slots: Vec<usize> = Vec::new();
    while let Some(batch) = shared.batcher.next_batch() {
        let now = Instant::now();
        shared.metrics.on_batch(batch.len());
        // Mixed batches ride together. Top-k jobs are scored once at the
        // batch's deepest k, then each response truncates to its own k (the
        // ranked prefix of a deeper selector is exactly the shallower
        // result). Threshold jobs keep per-query selectors — each carries
        // its own (threshold, limit) — so they batch without interfering.
        // lint: hot-path
        let mut max_k = 1usize;
        block.clear();
        tblock.clear();
        slots.clear();
        for pending in &batch {
            match pending.item.kind {
                QueryKind::TopK(k) => {
                    slots.push(block.len());
                    // lint: allow(hot-path-alloc) -- QueryBlock::push copies
                    // into the worker-lifetime lane buffer; it only grows
                    // until the buffer has warmed to the deepest batch, then
                    // reuses it.
                    block.push(&pending.item.query);
                    max_k = max_k.max(k);
                }
                QueryKind::Threshold(_) => {
                    slots.push(tblock.len());
                    // lint: allow(hot-path-alloc) -- same warmed lane buffer.
                    tblock.push(&pending.item.query);
                }
            }
        }
        let epoch_topk = if !block.is_empty() {
            shared.tiles.search_block(block.view(), max_k, &mut scratch, &mut out)
        } else {
            0
        };
        let epoch_thresh = if !tblock.is_empty() {
            matches.reset(tblock.len(), 0.0, 0);
            let mut ti = 0usize;
            for pending in &batch {
                if let QueryKind::Threshold(d) = pending.item.kind {
                    matches.selectors_mut()[ti].reset(d, pending.item.limit);
                    ti += 1;
                }
            }
            shared.tiles.search_block_matches(tblock.view(), &mut scratch, &mut matches)
        } else {
            0
        };
        // lint: end-hot-path
        let exec = now.elapsed();
        let batch_size = batch.len();
        for (qi, pending) in batch.into_iter().enumerate() {
            let queued = now.duration_since(pending.enqueued);
            let timing = RequestTiming { queued, exec, batch_size };
            match pending.item.kind {
                QueryKind::TopK(k) => {
                    shared.metrics.on_complete(queued, exec, k);
                    let ranked = out.query(slots[qi]);
                    let hits: Vec<SearchResult> = ranked.iter().take(k).cloned().collect();
                    // lint: allow(no-panic) -- non-empty by construction: the
                    // store refuses to delete its last row, submit_topk
                    // rejects k == 0, and search_block clamps k to the row
                    // count, so every selector holds at least one ranked hit.
                    let head = hits.first().expect("tile manager has rows");
                    let _ = pending.item.reply.send(SearchResponse {
                        winner: head.winner,
                        score: head.score,
                        hits,
                        truncated: false,
                        epoch: epoch_topk,
                        timing,
                    });
                }
                QueryKind::Threshold(_) => {
                    let ti = slots[qi];
                    let truncated = matches.truncated(ti);
                    shared.metrics.on_complete_threshold(queued, exec, truncated);
                    let hits: Vec<SearchResult> = matches.query(ti).to_vec();
                    // A threshold query can legitimately match nothing.
                    let (winner, score) = match hits.first() {
                        Some(head) => (head.winner, head.score),
                        None => (0, f64::NEG_INFINITY),
                    };
                    let _ = pending.item.reply.send(SearchResponse {
                        winner,
                        score,
                        hits,
                        truncated,
                        epoch: epoch_thresh,
                        timing,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::{AmEngine, DigitalExactEngine};
    use crate::util::rng;

    fn service(rows: usize, dims: usize, cfg: &CoordinatorConfig) -> (AmService, Vec<BitVec>) {
        let mut r = rng(7);
        let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
        let tiles = TileManager::build(words.clone(), 64, |w| {
            Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(DigitalExactEngine::new(w)))
        })
        .unwrap();
        (AmService::start(cfg, tiles), words)
    }

    #[test]
    fn serves_correct_results() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(100, 64, &cfg);
        let reference = DigitalExactEngine::new(words.clone());
        let mut r = rng(8);
        for _ in 0..30 {
            let q = BitVec::random(64, 0.5, &mut r);
            let resp = svc.search_blocking(q.clone()).unwrap();
            assert_eq!(resp.winner, reference.search(&q).winner);
            assert_eq!(resp.hits.len(), 1, "k defaults to 1");
            assert_eq!(resp.hits[0].winner, resp.winner);
            assert!(resp.timing.batch_size >= 1);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 30);
        svc.shutdown();
    }

    #[test]
    fn topk_responses_are_ranked_and_match_reference() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(80, 64, &cfg);
        let reference = DigitalExactEngine::new(words);
        let mut r = rng(9);
        for _ in 0..20 {
            let q = BitVec::random(64, 0.5, &mut r);
            let k = 1 + r.below(6);
            let resp = svc.search_topk_blocking(q.clone(), k).unwrap();
            let want = reference.search_topk(&q, k);
            assert_eq!(resp.hits.len(), want.len());
            for (a, b) in resp.hits.iter().zip(&want) {
                assert_eq!(a.winner, b.winner);
                assert_eq!(a.score, b.score);
            }
            assert_eq!(resp.winner, want[0].winner);
        }
        svc.shutdown();
    }

    #[test]
    fn k_larger_than_store_clamps() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        let resp = svc.search_topk_blocking(BitVec::zeros(64), 50).unwrap();
        assert_eq!(resp.hits.len(), 10);
        svc.shutdown();
    }

    #[test]
    fn k_beyond_policy_rejected() {
        let cfg = CoordinatorConfig { max_k: 4, ..CoordinatorConfig::default() };
        let (svc, _) = service(100, 64, &cfg);
        match svc.submit_topk(BitVec::zeros(64), 5) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("max_k"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        // At the cap it still serves.
        let resp = svc.search_topk_blocking(BitVec::zeros(64), 4).unwrap();
        assert_eq!(resp.hits.len(), 4);
        svc.shutdown();
    }

    /// A tile backed by a single-winner substrate (max_k = 1, like the XLA
    /// argmax artifact) must reject deep-k submissions up front instead of
    /// panicking a worker mid-batch.
    #[test]
    fn capability_limited_tiles_reject_deep_k_at_submit() {
        struct SingleWinner(DigitalExactEngine);
        impl AmEngine for SingleWinner {
            fn name(&self) -> &str {
                "single-winner"
            }
            fn metric(&self) -> crate::am::Metric {
                self.0.metric()
            }
            fn rows(&self) -> usize {
                self.0.rows()
            }
            fn dims(&self) -> usize {
                self.0.dims()
            }
            fn scores_into(&self, query: &BitVec, out: &mut Vec<f64>) {
                self.0.scores_into(query, out)
            }
            fn max_k(&self) -> usize {
                1
            }
            fn supports_threshold(&self) -> bool {
                false
            }
        }
        let mut r = rng(11);
        let words: Vec<BitVec> = (0..20).map(|_| BitVec::random(32, 0.5, &mut r)).collect();
        let tiles = TileManager::build(words, 8, |w| {
            Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(SingleWinner(
                DigitalExactEngine::new(w),
            )))
        })
        .unwrap();
        assert_eq!(tiles.max_k(), 1);
        let svc = AmService::start(&CoordinatorConfig::default(), tiles);
        match svc.submit_topk(BitVec::zeros(32), 5) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("capability"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        // The same substrate cannot enumerate a match set either: threshold
        // submissions are rejected up front, and the handle advertises it.
        assert!(!svc.supports_threshold());
        match svc.submit_threshold(BitVec::zeros(32), 1.0, 8) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("threshold"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        // k = 1 still serves normally.
        let resp = svc.search_blocking(BitVec::zeros(32)).unwrap();
        assert_eq!(resp.hits.len(), 1);
        svc.shutdown();
    }

    /// Threshold responses through the batched service must equal the flat
    /// engine's filtered-and-ranked score scan, entries and spill flag both.
    #[test]
    fn threshold_responses_match_flat_filter_reference() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(90, 64, &cfg);
        assert!(svc.supports_threshold());
        let reference = DigitalExactEngine::new(words);
        let mut r = rng(21);
        let mut scores = Vec::new();
        let mut saw_nonempty = 0usize;
        let mut saw_truncated = 0usize;
        for _ in 0..40 {
            let q = BitVec::random(64, 0.5, &mut r);
            reference.scores_into(&q, &mut scores);
            let (lo, hi) = scores.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &s| {
                (lo.min(s), hi.max(s))
            });
            // Sweep thresholds from below-min (match everything) to
            // above-max (match nothing).
            let d = lo + (hi - lo + 1.0) * (r.f64() * 1.3 - 0.1);
            let limit = 1 + r.below(20);
            let want = reference.search_matches(&q, d, limit);
            let resp = svc.search_threshold_blocking(q, d, limit).unwrap();
            assert_eq!(resp.hits, want.as_slice(), "d={d} limit={limit}");
            assert_eq!(resp.truncated, want.truncated(), "d={d} limit={limit}");
            match want.best() {
                Some(head) => {
                    assert_eq!(resp.winner, head.winner);
                    assert_eq!(resp.score, head.score);
                    saw_nonempty += 1;
                }
                None => {
                    assert_eq!(resp.winner, 0);
                    assert_eq!(resp.score, f64::NEG_INFINITY);
                }
            }
            if resp.truncated {
                saw_truncated += 1;
            }
        }
        assert!(saw_nonempty > 0, "sweep never produced a match");
        assert!(saw_truncated > 0, "sweep never spilled a bound");
        let m = svc.metrics();
        let lane = m.kinds.iter().find(|l| l.kind == "threshold").expect("threshold lane");
        assert_eq!(lane.completed, 40);
        assert_eq!(lane.truncated, saw_truncated as u64);
        svc.shutdown();
    }

    /// Top-k and threshold queries riding the same batches must each come
    /// back exact — the worker partitions the mixed batch by kind.
    #[test]
    fn concurrent_mixed_kind_requests_each_served_exactly() {
        let cfg = CoordinatorConfig {
            max_batch: 32,
            max_wait_us: 200,
            queue_depth: 2048,
            workers: 3,
            ..CoordinatorConfig::default()
        };
        let (svc, words) = service(120, 64, &cfg);
        let reference = DigitalExactEngine::new(words);
        let errors = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..6u64 {
                let svc = svc.clone();
                let reference = &reference;
                let errors = &errors;
                s.spawn(move || {
                    let mut r = rng(700 + t);
                    let mut scores = Vec::new();
                    for i in 0..40 {
                        let q = BitVec::random(64, 0.5, &mut r);
                        if (t as usize + i) % 2 == 0 {
                            let k = 1 + r.below(6);
                            match svc.search_topk_with_retry(q.clone(), k, 10) {
                                Ok(resp) => {
                                    let want = reference.search_topk(&q, k);
                                    if resp.hits != want || resp.truncated {
                                        errors.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                Err(_) => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        } else {
                            reference.scores_into(&q, &mut scores);
                            let hi = scores.iter().fold(f64::MIN, |a, &s| a.max(s));
                            let d = hi - (r.f64() * 8.0);
                            let limit = 1 + r.below(12);
                            let want = reference.search_matches(&q, d, limit);
                            // Threshold submissions share the retry shape.
                            let mut resp = svc.search_threshold_blocking(q.clone(), d, limit);
                            let mut tries = 0;
                            while matches!(resp, Err(SubmitError::Busy)) && tries < 10 {
                                tries += 1;
                                std::thread::sleep(Duration::from_micros(100));
                                resp = svc.search_threshold_blocking(q.clone(), d, limit);
                            }
                            match resp {
                                Ok(resp) => {
                                    if resp.hits != want.as_slice()
                                        || resp.truncated != want.truncated()
                                    {
                                        errors.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                Err(_) => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(errors.load(Ordering::Relaxed), 0, "every mixed-kind response exact");
        let m = svc.metrics();
        assert_eq!(m.completed, 240);
        let by_kind: u64 = m.kinds.iter().map(|l| l.completed).sum();
        assert_eq!(by_kind, 240, "every completion lands in a kind lane");
        assert_eq!(m.kinds.len(), 2, "both kind lanes active");
        svc.shutdown();
    }

    /// Threshold gate battery: zero limit, non-finite thresholds and
    /// beyond-policy limits are typed rejections before any queueing.
    #[test]
    fn threshold_gates_reject_bad_submissions() {
        let cfg = CoordinatorConfig { max_matches: 16, ..CoordinatorConfig::default() };
        let (svc, _) = service(30, 64, &cfg);
        match svc.submit_threshold(BitVec::zeros(64), 1.0, 0) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("limit"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        match svc.submit_threshold(BitVec::zeros(64), f64::NAN, 4) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("finite"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        match svc.submit_threshold(BitVec::zeros(64), 1.0, 17) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("max_matches"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        match svc.submit_threshold(BitVec::zeros(32), 1.0, 4) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("64"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        // At the cap it still serves (threshold below min matches all rows,
        // so the bound spills and the typed flag comes back set).
        let resp = svc.search_threshold_blocking(BitVec::zeros(64), f64::MIN, 16).unwrap();
        assert_eq!(resp.hits.len(), 16);
        assert!(resp.truncated, "30 rows through a 16-limit must truncate");
        let svc2 = svc.clone();
        svc.shutdown();
        assert!(matches!(
            svc2.submit_threshold(BitVec::zeros(64), 1.0, 4),
            Err(SubmitError::Closed)
        ));
    }

    #[test]
    fn zero_k_rejected_immediately() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        match svc.submit_topk(BitVec::zeros(64), 0) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("k"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn self_queries_return_self() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(50, 64, &cfg);
        for (i, w) in words.iter().enumerate().take(10) {
            let resp = svc.search_blocking(w.clone()).unwrap();
            assert_eq!(resp.winner, i);
        }
        svc.shutdown();
    }

    #[test]
    fn bad_query_rejected_immediately() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        match svc.submit(BitVec::zeros(32)) {
            Err(SubmitError::BadQuery(_)) => {}
            other => panic!("expected BadQuery, got {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn closed_after_shutdown() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        let svc2 = svc.clone();
        svc.shutdown();
        assert!(matches!(svc2.submit(BitVec::zeros(64)), Err(SubmitError::Closed)));
    }

    #[test]
    fn concurrent_clients_all_served() {
        let cfg =
            CoordinatorConfig { max_batch: 16, max_wait_us: 100, queue_depth: 1024, workers: 3, ..CoordinatorConfig::default() };
        let (svc, words) = service(200, 64, &cfg);
        let reference = DigitalExactEngine::new(words);
        let errors = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..6 {
                let svc = svc.clone();
                let reference = &reference;
                let errors = &errors;
                s.spawn(move || {
                    let mut r = rng(50 + t);
                    for _ in 0..50 {
                        let q = BitVec::random(64, 0.5, &mut r);
                        match svc.search_with_retry(q.clone(), 10) {
                            Ok(resp) => {
                                if resp.winner != reference.search(&q).winner {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(errors.load(Ordering::Relaxed), 0);
        let m = svc.metrics();
        assert_eq!(m.completed, 300);
        assert!(m.mean_batch_size >= 1.0);
        svc.shutdown();
    }

    /// Mixed-k requests submitted concurrently ride shared batches; each
    /// response must carry exactly its own k (prefix of the deeper ranking).
    #[test]
    fn concurrent_mixed_k_requests_each_get_their_k() {
        let cfg =
            CoordinatorConfig { max_batch: 32, max_wait_us: 200, queue_depth: 2048, workers: 3, ..CoordinatorConfig::default() };
        let (svc, words) = service(120, 64, &cfg);
        let reference = DigitalExactEngine::new(words);
        let errors = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..6u64 {
                let svc = svc.clone();
                let reference = &reference;
                let errors = &errors;
                s.spawn(move || {
                    let mut r = rng(400 + t);
                    let k = 1 + (t as usize % 4) * 3; // 1, 4, 7, 10 mixed
                    for _ in 0..40 {
                        let q = BitVec::random(64, 0.5, &mut r);
                        match svc.search_topk_with_retry(q.clone(), k, 10) {
                            Ok(resp) => {
                                let want = reference.search_topk(&q, k);
                                let ok = resp.hits.len() == want.len()
                                    && resp
                                        .hits
                                        .iter()
                                        .zip(&want)
                                        .all(|(a, b)| a.winner == b.winner && a.score == b.score);
                                if !ok {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(errors.load(Ordering::Relaxed), 0, "every mixed-k response exact");
        let m = svc.metrics();
        assert_eq!(m.completed, 240);
        assert!(!m.per_k.is_empty(), "per-k lanes recorded");
        let lanes: usize = m.per_k.iter().map(|l| l.completed as usize).sum();
        assert_eq!(lanes, 240, "every completion lands in a k lane");
        svc.shutdown();
    }

    #[test]
    fn admin_update_reflects_in_subsequent_searches() {
        let cfg = CoordinatorConfig::default();
        let (svc, words) = service(60, 64, &cfg);
        let epoch0 = svc.epoch();
        assert_eq!(epoch0, 0);

        // Update row 7 to a fresh word through the admin plane.
        let mut r = rng(31);
        let new_word = BitVec::random(64, 0.5, &mut r);
        let resp = svc.admin(AdminOp::Update { row: 7, word: new_word.clone() }).unwrap();
        assert_eq!(resp.row, 7);
        assert_eq!(resp.rows, 60);
        assert!(resp.epoch > epoch0);
        let report = resp.write.expect("update programs the array");
        assert_eq!(report.failures, 0);
        assert!(report.energy > 0.0 && report.latency > 0.0);

        // Subsequent searches observe the update and carry the new epoch.
        let hit = svc.search_topk_blocking(new_word.clone(), 2).unwrap();
        assert_eq!(hit.winner, 7, "updated word must win its own search");
        assert!(hit.epoch >= resp.epoch);
        // The old word no longer lives at row 7 (an exact self-match would
        // score exactly its popcount).
        let old = svc.search_blocking(words[7].clone()).unwrap();
        let self_score = f64::from(words[7].count_ones());
        assert!(
            old.winner != 7 || (old.score - self_score).abs() > 1e-9,
            "row 7 still serves the pre-update word"
        );

        let m = svc.metrics();
        assert_eq!(m.admin.len(), 1);
        assert_eq!(m.admin[0].kind, "update");
        assert_eq!(m.admin[0].completed, 1);
        assert_eq!(m.write.cells, 64);
        assert!(m.write.pulses as usize >= 64);
        assert!(m.write.energy_j > 0.0);
        svc.shutdown();
    }

    #[test]
    fn admin_insert_and_delete_resize_the_store() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        let mut r = rng(33);
        let w = BitVec::random(64, 0.5, &mut r);
        let resp = svc.admin(AdminOp::Insert { word: w.clone() }).unwrap();
        assert_eq!(resp.row, 10);
        assert_eq!(resp.rows, 11);
        assert_eq!(svc.rows(), 11);
        let hit = svc.search_blocking(w.clone()).unwrap();
        assert_eq!(hit.winner, 10, "inserted row is searchable");

        let resp = svc.admin(AdminOp::Delete { row: 10 }).unwrap();
        assert_eq!(resp.rows, 10);
        assert!(resp.write.is_none(), "delete spends no programming pulses");
        assert_eq!(svc.rows(), 10);
        assert_eq!(svc.snapshot_words().len(), 10);

        let m = svc.metrics();
        let kinds: Vec<&str> = m.admin.iter().map(|l| l.kind).collect();
        assert_eq!(kinds, vec!["insert", "delete"]);
        svc.shutdown();
    }

    #[test]
    fn admin_rejects_bad_ops_and_counts_them() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(10, 64, &cfg);
        // Wrong dims.
        match svc.admin(AdminOp::Insert { word: BitVec::zeros(32) }) {
            Err(SubmitError::BadQuery(msg)) => assert!(msg.contains("64"), "{msg}"),
            other => panic!("expected BadQuery, got {other:?}"),
        }
        // Row out of range.
        assert!(matches!(
            svc.admin(AdminOp::Update { row: 99, word: BitVec::zeros(64) }),
            Err(SubmitError::BadQuery(_))
        ));
        assert!(matches!(
            svc.admin(AdminOp::Delete { row: 99 }),
            Err(SubmitError::BadQuery(_))
        ));
        assert_eq!(svc.metrics().admin_rejected, 3);
        let svc2 = svc.clone();
        svc.shutdown();
        assert!(matches!(
            svc2.admin(AdminOp::Delete { row: 0 }),
            Err(SubmitError::Closed)
        ));
    }

    /// Admin CAS at the service level: a pinned epoch only commits while it
    /// still matches; a stale pin is a typed `EpochMismatch` rejection and
    /// the store stays unchanged — the safe concurrent-writer retry loop.
    #[test]
    fn admin_cas_rejects_stale_expected_epoch() {
        let cfg = CoordinatorConfig::default();
        let (svc, _) = service(20, 64, &cfg);
        let mut r = rng(41);
        let w = BitVec::random(64, 0.5, &mut r);
        let e0 = svc.epoch();
        let resp = svc.admin_cas(AdminOp::Update { row: 1, word: w.clone() }, Some(e0)).unwrap();
        assert!(resp.epoch > e0, "matching CAS commits");

        let w2 = BitVec::random(64, 0.5, &mut r);
        match svc.admin_cas(AdminOp::Update { row: 2, word: w2 }, Some(e0)) {
            Err(SubmitError::EpochMismatch { expected, actual }) => {
                assert_eq!(expected, e0);
                assert_eq!(actual, resp.epoch);
            }
            other => panic!("expected EpochMismatch, got {other:?}"),
        }
        assert_eq!(svc.epoch(), resp.epoch, "rejected CAS must not bump the epoch");
        assert_eq!(svc.metrics().admin_rejected, 1);

        // The canonical retry: re-read the epoch, pin it, commit.
        let w3 = BitVec::random(64, 0.5, &mut r);
        let retry = svc.admin_cas(AdminOp::Update { row: 2, word: w3 }, Some(svc.epoch())).unwrap();
        assert!(retry.epoch > resp.epoch);
        svc.shutdown();
    }

    /// A word whose cells fail write-verify must be rejected — the serving
    /// store never holds bits the array could not actually program.
    #[test]
    fn admin_write_verify_failure_rejected() {
        let mut full = CosimeConfig::default();
        full.write.pulse_scale = 0.4; // sub-coercive: can never switch
        let mut r = rng(35);
        let words: Vec<BitVec> = (0..10).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tiles = TileManager::build(words.clone(), 64, |w| {
            Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(DigitalExactEngine::new(w)))
        })
        .unwrap();
        let svc = AmService::start_with_config(&full, tiles);
        let target = BitVec::random(64, 0.5, &mut r);
        match svc.admin(AdminOp::Update { row: 2, word: target }) {
            Err(SubmitError::WriteFailed(msg)) => assert!(msg.contains("stuck"), "{msg}"),
            other => panic!("expected WriteFailed, got {other:?}"),
        }
        // Store unchanged: the old word still serves.
        let hit = svc.search_blocking(words[2].clone()).unwrap();
        assert_eq!(hit.winner, 2);
        assert_eq!(hit.epoch, 0, "no epoch bump on a rejected write");
        let m = svc.metrics();
        assert_eq!(m.admin_rejected, 1);
        // The pulses were fired even though verify failed: the cost metrics
        // must account them (mirroring AmStore's accounting policy).
        assert!(m.write.pulses > 0 && m.write.energy_j > 0.0, "spent pulses accounted");
        svc.shutdown();
    }

    #[test]
    fn backpressure_under_tiny_queue() {
        // One slow worker + depth 1: bursts must hit Busy, not hang.
        let cfg = CoordinatorConfig { max_batch: 1, max_wait_us: 1, queue_depth: 1, workers: 1, ..CoordinatorConfig::default() };
        let (svc, _) = service(2000, 256, &cfg);
        let mut r = rng(9);
        let mut busy = 0;
        let mut rxs = Vec::new();
        for _ in 0..200 {
            match svc.submit(BitVec::random(256, 0.5, &mut r)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Busy) => busy += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(busy > 0, "tiny queue must reject some of a 200-burst");
        for rx in rxs {
            let _ = rx.recv();
        }
        assert_eq!(svc.metrics().rejected_busy as usize, busy);
        svc.shutdown();
    }

    /// Pull every snapshot chunk from `primary` (pinning the first chunk's
    /// epoch), build a fresh service over the streamed rows, and seed its
    /// epoch to the cut.
    fn replica_from_snapshot(primary: &AmService, cfg: &CosimeConfig) -> AmService {
        let mut rows: Vec<BitVec> = Vec::new();
        let mut pin = None;
        loop {
            let chunk = primary.snapshot_chunk(pin, rows.len() as u64, 7).unwrap();
            pin = Some(chunk.epoch);
            rows.extend(chunk.rows);
            if rows.len() as u64 >= chunk.total_rows {
                let tiles = TileManager::build(rows, 64, |w| {
                    Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(DigitalExactEngine::new(w)))
                })
                .unwrap();
                tiles.seed_epoch(chunk.epoch);
                return AmService::start_with_config(cfg, tiles);
            }
        }
    }

    #[test]
    fn snapshot_plus_catchup_replays_bit_exact() {
        let full = CosimeConfig::default();
        let (svc, _) = service(40, 64, &full.coordinator);
        let mut r = rng(21);
        // Commit some history before the cut...
        for _ in 0..3 {
            svc.admin(AdminOp::Insert { word: BitVec::random(64, 0.5, &mut r) }).unwrap();
        }
        let replica = replica_from_snapshot(&svc, &full);
        assert_eq!(replica.epoch(), svc.epoch(), "replica seeded to the cut epoch");
        assert_eq!(replica.rows(), svc.rows());
        // ...then more after it: update, insert, delete.
        svc.admin(AdminOp::Update { row: 5, word: BitVec::random(64, 0.5, &mut r) }).unwrap();
        svc.admin(AdminOp::Insert { word: BitVec::random(64, 0.5, &mut r) }).unwrap();
        svc.admin(AdminOp::Delete { row: 0 }).unwrap();
        // Replay the catch-up feed to the serving epoch.
        loop {
            let batch = svc.catchup(replica.epoch()).unwrap();
            for e in batch.entries {
                replica.apply_replicated(e).unwrap();
            }
            if replica.epoch() >= batch.serving_epoch {
                break;
            }
        }
        assert_eq!(replica.epoch(), svc.epoch());
        // Bit-exact: identical winners and scores on both stores (the log
        // carries the programmed words, so no RNG divergence).
        for _ in 0..20 {
            let q = BitVec::random(64, 0.5, &mut r);
            let a = svc.search_topk_blocking(q.clone(), 3).unwrap();
            let b = replica.search_topk_blocking(q, 3).unwrap();
            assert_eq!(a.hits.len(), b.hits.len());
            for (x, y) in a.hits.iter().zip(&b.hits) {
                assert_eq!((x.winner, x.score), (y.winner, y.score));
            }
        }
        // The caught-up replica can itself serve replication.
        let batch = replica.catchup(svc.epoch() - 1).unwrap();
        assert_eq!(batch.entries.len(), 1);
        replica.shutdown();
        svc.shutdown();
    }

    #[test]
    fn catchup_log_is_bounded_and_truncation_is_typed() {
        let mut full = CosimeConfig::default();
        full.replication.log_capacity = 4;
        let mut r = rng(22);
        let words: Vec<BitVec> = (0..8).map(|_| BitVec::random(64, 0.5, &mut r)).collect();
        let tiles = TileManager::build(words, 64, |w| {
            Ok::<Box<dyn AmEngine>, anyhow::Error>(Box::new(DigitalExactEngine::new(w)))
        })
        .unwrap();
        let svc = AmService::start_with_config(&full, tiles);
        for _ in 0..7 {
            svc.admin(AdminOp::Insert { word: BitVec::random(64, 0.5, &mut r) }).unwrap();
        }
        // Epochs 1..=7 committed, capacity 4: the log holds (3, 7].
        let ok = svc.catchup(3).unwrap();
        assert_eq!(ok.entries.len(), 4);
        assert_eq!(ok.serving_epoch, 7);
        match svc.catchup(2) {
            Err(SubmitError::LogTruncated { floor }) => assert_eq!(floor, 3),
            other => panic!("expected LogTruncated, got {other:?}"),
        }
        // The floor is also advertised on snapshot chunks.
        let chunk = svc.snapshot_chunk(None, 0, 1).unwrap();
        assert_eq!(chunk.log_floor, 3);
        assert_eq!(chunk.total_rows, 15);
        svc.shutdown();
    }

    #[test]
    fn snapshot_pin_rejects_mid_stream_commits() {
        let full = CosimeConfig::default();
        let (svc, _) = service(10, 64, &full.coordinator);
        let first = svc.snapshot_chunk(None, 0, 4).unwrap();
        assert_eq!(first.rows.len(), 4);
        let mut r = rng(23);
        svc.admin(AdminOp::Insert { word: BitVec::random(64, 0.5, &mut r) }).unwrap();
        match svc.snapshot_chunk(Some(first.epoch), 4, 4) {
            Err(SubmitError::EpochMismatch { expected, actual }) => {
                assert_eq!(expected, first.epoch);
                assert_eq!(actual, first.epoch + 1);
            }
            other => panic!("expected EpochMismatch, got {other:?}"),
        }
        // An unpinned pull (restart) sees the new epoch.
        assert_eq!(svc.snapshot_chunk(None, 0, 4).unwrap().epoch, first.epoch + 1);
        svc.shutdown();
    }

    #[test]
    fn replicated_entries_must_arrive_in_epoch_order() {
        let full = CosimeConfig::default();
        let (svc, _) = service(10, 64, &full.coordinator);
        let mut r = rng(24);
        let word = BitVec::random(64, 0.5, &mut r);
        // Store is at epoch 0; an entry claiming epoch 5 must not apply.
        let entry = CatchupEntry { epoch: 5, cmd: AdminCmd::Insert { word } };
        match svc.apply_replicated(entry) {
            Err(SubmitError::EpochMismatch { expected, actual }) => {
                assert_eq!((expected, actual), (4, 0));
            }
            other => panic!("expected EpochMismatch, got {other:?}"),
        }
        assert_eq!(svc.rows(), 10, "store unchanged after the rejected entry");
        svc.shutdown();
    }
}
