//! Serving metrics: counters and latency histograms for the queue, the
//! engine execution, and end-to-end request time.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::util::Histogram;

/// Per-k latency lane: requests asking for the same top-k depth share a
/// histogram, so a deployment can see whether deep-k readouts (iterated WTA
/// passes) cost more end to end.
struct KLane {
    completed: u64,
    total_us: Histogram,
}

/// The one latency histogram shape (µs, log-spaced) every lane shares, so
/// global and per-k percentiles stay comparable.
fn latency_histogram() -> Histogram {
    Histogram::log_spaced(0.5, 10_000_000.0, 120)
}

/// Lane key for a requested k: exact up to 16, rounded up to the next power
/// of two beyond that. Even with the service's submit-time `max_k` policy
/// cap, a caller recording raw k values here must not be able to grow one
/// histogram per distinct k forever; this bounds the lane count.
fn k_lane(k: usize) -> usize {
    if k <= 16 {
        k
    } else {
        // checked: k near usize::MAX has no next power of two.
        k.checked_next_power_of_two().unwrap_or(usize::MAX)
    }
}

struct Inner {
    submitted: u64,
    completed: u64,
    rejected_busy: u64,
    batches: u64,
    batch_sizes: Vec<u64>,
    queue_us: Histogram,
    exec_us: Histogram,
    total_us: Histogram,
    per_k: BTreeMap<usize, KLane>,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Per-k latency summary (one row per lane; the key is the requested k,
/// exact up to 16 and rounded up to a power of two beyond that).
#[derive(Debug, Clone)]
pub struct PerKSnapshot {
    pub k: usize,
    pub completed: u64,
    pub total_p50_us: f64,
    pub total_p99_us: f64,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected_busy: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub total_p50_us: f64,
    pub total_p99_us: f64,
    pub total_mean_us: f64,
    /// Latency broken down by requested k, ascending k.
    pub per_k: Vec<PerKSnapshot>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        let h = latency_histogram;
        Metrics {
            inner: Mutex::new(Inner {
                submitted: 0,
                completed: 0,
                rejected_busy: 0,
                batches: 0,
                batch_sizes: Vec::new(),
                queue_us: h(),
                exec_us: h(),
                total_us: h(),
                per_k: BTreeMap::new(),
            }),
        }
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_reject_busy(&self) {
        self.inner.lock().unwrap().rejected_busy += 1;
    }

    pub fn on_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_sizes.push(size as u64);
    }

    pub fn on_complete(&self, queued: Duration, exec: Duration, k: usize) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        let qu = queued.as_secs_f64() * 1e6;
        let ex = exec.as_secs_f64() * 1e6;
        g.queue_us.record(qu.max(0.5));
        g.exec_us.record(ex.max(0.5));
        g.total_us.record((qu + ex).max(0.5));
        let lane = g
            .per_k
            .entry(k_lane(k))
            .or_insert_with(|| KLane { completed: 0, total_us: latency_histogram() });
        lane.completed += 1;
        lane.total_us.record((qu + ex).max(0.5));
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.iter().sum::<u64>() as f64 / g.batch_sizes.len() as f64
        };
        MetricsSnapshot {
            submitted: g.submitted,
            completed: g.completed,
            rejected_busy: g.rejected_busy,
            batches: g.batches,
            mean_batch_size: mean_batch,
            queue_p50_us: g.queue_us.quantile(0.5),
            queue_p99_us: g.queue_us.quantile(0.99),
            exec_p50_us: g.exec_us.quantile(0.5),
            exec_p99_us: g.exec_us.quantile(0.99),
            total_p50_us: g.total_us.quantile(0.5),
            total_p99_us: g.total_us.quantile(0.99),
            total_mean_us: g.total_us.mean(),
            per_k: g
                .per_k
                .iter()
                .map(|(&k, lane)| PerKSnapshot {
                    k,
                    completed: lane.completed,
                    total_p50_us: lane.total_us.quantile(0.5),
                    total_p99_us: lane.total_us.quantile(0.99),
                })
                .collect(),
        }
    }
}

impl MetricsSnapshot {
    /// Human-readable report block.
    pub fn report(&self) -> String {
        let mut out = format!(
            "requests: submitted={} completed={} rejected(busy)={}\n\
             batches: {} (mean size {:.1})\n\
             queue  µs: p50={:.1} p99={:.1}\n\
             exec   µs: p50={:.1} p99={:.1}\n\
             total  µs: p50={:.1} p99={:.1} mean={:.1}",
            self.submitted,
            self.completed,
            self.rejected_busy,
            self.batches,
            self.mean_batch_size,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.total_p50_us,
            self.total_p99_us,
            self.total_mean_us,
        );
        for lane in &self.per_k {
            out.push_str(&format!(
                "\n  k={:<4} n={:<8} total µs: p50={:.1} p99={:.1}",
                lane.k, lane.completed, lane.total_p50_us, lane.total_p99_us
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject_busy();
        m.on_batch(8);
        m.on_batch(4);
        m.on_complete(Duration::from_micros(100), Duration::from_micros(50), 1);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected_busy, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.completed, 1);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-9);
        assert!(s.total_p50_us >= 100.0);
    }

    #[test]
    fn per_k_lanes_split_latency() {
        let m = Metrics::new();
        m.on_complete(Duration::from_micros(10), Duration::from_micros(10), 1);
        m.on_complete(Duration::from_micros(10), Duration::from_micros(10), 1);
        m.on_complete(Duration::from_micros(500), Duration::from_micros(500), 8);
        let s = m.snapshot();
        assert_eq!(s.per_k.len(), 2);
        assert_eq!(s.per_k[0].k, 1);
        assert_eq!(s.per_k[0].completed, 2);
        assert_eq!(s.per_k[1].k, 8);
        assert_eq!(s.per_k[1].completed, 1);
        assert!(
            s.per_k[1].total_p50_us > s.per_k[0].total_p50_us,
            "deep-k lane must show its higher latency"
        );
    }

    #[test]
    fn large_k_values_share_bounded_lanes() {
        let m = Metrics::new();
        for k in [17usize, 25, 32, 1000, 1 << 40] {
            m.on_complete(Duration::from_micros(10), Duration::from_micros(10), k);
        }
        let s = m.snapshot();
        let keys: Vec<usize> = s.per_k.iter().map(|l| l.k).collect();
        assert_eq!(keys, vec![32, 1024, 1 << 40], "power-of-two lanes above 16");
        assert_eq!(s.per_k[0].completed, 3, "17, 25 and 32 share the 32 lane");
        // Absurd k must not overflow the lane computation.
        m.on_complete(Duration::from_micros(1), Duration::from_micros(1), usize::MAX - 1);
        assert!(m.snapshot().per_k.iter().any(|l| l.k == usize::MAX));
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.on_submit();
        m.on_complete(Duration::from_micros(10), Duration::from_micros(5), 3);
        let text = m.snapshot().report();
        assert!(text.contains("submitted=1"));
        assert!(text.contains("total"));
        assert!(text.contains("k=3"), "{text}");
    }
}
