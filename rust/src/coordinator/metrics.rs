//! Serving metrics: counters and latency histograms for the queue, the
//! engine execution, and end-to-end request time — plus admin-plane lanes
//! (live store mutations) with cumulative write-verify cost accounting.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::am::write::WriteReport;
use crate::util::sync::{TrackedMutex, METRICS_COUNTERS};
use crate::util::Histogram;

/// Admin-plane operation kind — each gets its own metrics lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdminKind {
    /// Reprogram an existing row in place.
    Update,
    /// Append a new row to the store.
    Insert,
    /// Remove a row (rows above shift down).
    Delete,
}

impl AdminKind {
    /// Stable lowercase name, as printed in reports and wire payloads.
    pub fn name(self) -> &'static str {
        match self {
            AdminKind::Update => "update",
            AdminKind::Insert => "insert",
            AdminKind::Delete => "delete",
        }
    }

    fn idx(self) -> usize {
        match self {
            AdminKind::Update => 0,
            AdminKind::Insert => 1,
            AdminKind::Delete => 2,
        }
    }

    const ALL: [AdminKind; 3] = [AdminKind::Update, AdminKind::Insert, AdminKind::Delete];
}

/// Search query kind — each gets its own completion lane, so a deployment
/// serving both top-k and threshold traffic can see the split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchKind {
    /// Ranked best-k readout.
    TopK,
    /// Bounded match-set enumeration at a score threshold.
    Threshold,
}

impl SearchKind {
    /// Stable lowercase name, as printed in reports and wire payloads.
    pub fn name(self) -> &'static str {
        match self {
            SearchKind::TopK => "topk",
            SearchKind::Threshold => "threshold",
        }
    }

    fn idx(self) -> usize {
        match self {
            SearchKind::TopK => 0,
            SearchKind::Threshold => 1,
        }
    }

    const ALL: [SearchKind; 2] = [SearchKind::TopK, SearchKind::Threshold];
}

/// Per-query-kind completion lane.
struct KindLane {
    completed: u64,
    truncated: u64,
    total_us: Histogram,
}

/// Per-k latency lane: requests asking for the same top-k depth share a
/// histogram, so a deployment can see whether deep-k readouts (iterated WTA
/// passes) cost more end to end.
struct KLane {
    completed: u64,
    total_us: Histogram,
}

/// Bucket layout of the shared latency histogram shape (µs):
/// `Histogram::log_spaced(LATENCY_HIST_LO, LATENCY_HIST_HI, LATENCY_HIST_BUCKETS)`.
/// Every latency lane — local or decoded off the wire — uses this exact
/// layout, which is what makes [`Histogram::merge_from`] across lanes (and
/// across shards) an *exact* quantile merge.
pub const LATENCY_HIST_LO: f64 = 0.5;
/// See [`LATENCY_HIST_LO`].
pub const LATENCY_HIST_HI: f64 = 10_000_000.0;
/// See [`LATENCY_HIST_LO`].
pub const LATENCY_HIST_BUCKETS: usize = 120;

/// The one latency histogram shape (µs, log-spaced) every lane shares, so
/// global and per-k percentiles stay comparable — and mergeable across
/// shards bucket by bucket.
pub fn latency_histogram() -> Histogram {
    Histogram::log_spaced(LATENCY_HIST_LO, LATENCY_HIST_HI, LATENCY_HIST_BUCKETS)
}

/// Lane key for a requested k: exact up to 16, rounded up to the next power
/// of two beyond that. Even with the service's submit-time `max_k` policy
/// cap, a caller recording raw k values here must not be able to grow one
/// histogram per distinct k forever; this bounds the lane count.
fn k_lane(k: usize) -> usize {
    if k <= 16 {
        k
    } else {
        // checked: k near usize::MAX has no next power of two.
        k.checked_next_power_of_two().unwrap_or(usize::MAX)
    }
}

/// Per-admin-kind latency lane.
struct AdminLane {
    completed: u64,
    total_us: Histogram,
}

struct Inner {
    submitted: u64,
    completed: u64,
    rejected_busy: u64,
    batches: u64,
    batch_sizes: Vec<u64>,
    queue_us: Histogram,
    exec_us: Histogram,
    total_us: Histogram,
    per_k: BTreeMap<usize, KLane>,
    kinds: [KindLane; 2],
    admin: [AdminLane; 3],
    admin_rejected: u64,
    degraded: u64,
    write_cells: u64,
    write_pulses: u64,
    write_energy_j: f64,
    write_latency_s: f64,
}

impl Inner {
    fn absorb_write(&mut self, r: &WriteReport) {
        self.write_cells += r.cells as u64;
        self.write_pulses += r.pulses as u64;
        self.write_energy_j += r.energy;
        self.write_latency_s += r.latency;
    }
}

/// Thread-safe metrics sink. The counter block is the `metrics.counters`
/// lock class — innermost in [`crate::util::sync::lock_order`], so any
/// serving path may record while holding its own locks.
pub struct Metrics {
    counters: TrackedMutex<Inner>,
}

/// Per-k latency summary (one row per lane; the key is the requested k,
/// exact up to 16 and rounded up to a power of two beyond that).
#[derive(Debug, Clone)]
pub struct PerKSnapshot {
    /// Requested k of this lane (exact up to 16, else next power of two).
    pub k: usize,
    /// Searches completed in this lane.
    pub completed: u64,
    /// End-to-end p50 in microseconds.
    pub total_p50_us: f64,
    /// End-to-end p99 in microseconds.
    pub total_p99_us: f64,
    /// The lane's full histogram (shared layout, see [`latency_histogram`]);
    /// `None` on snapshots reconstructed from sources that do not carry it.
    pub hist: Option<Histogram>,
}

/// Per-query-kind completion summary (only kinds that completed at least
/// once).
#[derive(Debug, Clone)]
pub struct KindLaneSnapshot {
    /// Lane name (`topk`/`threshold`).
    pub kind: &'static str,
    /// Searches completed in this lane.
    pub completed: u64,
    /// Threshold lane only: responses whose match set spilled past the
    /// request's bound (always 0 in the top-k lane).
    pub truncated: u64,
    /// End-to-end p50 in microseconds.
    pub total_p50_us: f64,
    /// End-to-end p99 in microseconds.
    pub total_p99_us: f64,
    /// The lane's full histogram; `None` when the source did not carry it.
    pub hist: Option<Histogram>,
}

/// Per-admin-kind latency summary (only kinds that completed at least once).
#[derive(Debug, Clone)]
pub struct AdminLaneSnapshot {
    /// Lane name (`update`/`insert`/`delete`).
    pub kind: &'static str,
    /// Admin ops completed in this lane.
    pub completed: u64,
    /// End-to-end p50 in microseconds.
    pub total_p50_us: f64,
    /// End-to-end p99 in microseconds.
    pub total_p99_us: f64,
    /// The lane's full histogram; `None` when the source did not carry it.
    pub hist: Option<Histogram>,
}

/// The three main latency histograms of a snapshot (shared layout). Their
/// presence is what turns cross-shard aggregation into an *exact* quantile
/// merge instead of a worst-shard approximation.
#[derive(Debug, Clone)]
pub struct LatencyHists {
    /// Queue-wait latency in microseconds.
    pub queue_us: Histogram,
    /// Kernel-execution latency in microseconds.
    pub exec_us: Histogram,
    /// End-to-end latency in microseconds.
    pub total_us: Histogram,
}

/// Cumulative write-verify cost of the admin plane (from the ±4 V
/// programming loop's pulse-accurate reports).
#[derive(Debug, Clone, Copy, Default)]
pub struct WriteCostSnapshot {
    /// Cells touched by verified writes.
    pub cells: u64,
    /// Program/verify pulses issued.
    pub pulses: u64,
    /// Modeled write energy in joules.
    pub energy_j: f64,
    /// Modeled cumulative write latency in seconds.
    pub latency_s: f64,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Search requests accepted into the queue.
    pub submitted: u64,
    /// Search requests completed.
    pub completed: u64,
    /// Search requests rejected with `busy` backpressure.
    pub rejected_busy: u64,
    /// Batches executed by the worker.
    pub batches: u64,
    /// Mean formed-batch size.
    pub mean_batch_size: f64,
    /// Queue-wait p50 in microseconds.
    pub queue_p50_us: f64,
    /// Queue-wait p99 in microseconds.
    pub queue_p99_us: f64,
    /// Kernel-execution p50 in microseconds.
    pub exec_p50_us: f64,
    /// Kernel-execution p99 in microseconds.
    pub exec_p99_us: f64,
    /// End-to-end p50 in microseconds.
    pub total_p50_us: f64,
    /// End-to-end p99 in microseconds.
    pub total_p99_us: f64,
    /// End-to-end mean in microseconds.
    pub total_mean_us: f64,
    /// Latency broken down by requested k, ascending k.
    pub per_k: Vec<PerKSnapshot>,
    /// Completions broken down by query kind (`topk`/`threshold`), only the
    /// active lanes; the threshold lane also counts truncated responses.
    pub kinds: Vec<KindLaneSnapshot>,
    /// Admin-plane lanes (update/insert/delete), only the active ones.
    pub admin: Vec<AdminLaneSnapshot>,
    /// Admin ops rejected (bad row, dims mismatch, verify failure).
    pub admin_rejected: u64,
    /// Search batches served *degraded*: a scatter-gather answer assembled
    /// without one or more unhealthy shards (its responses carried the
    /// typed partial flag). Always 0 on a flat local stack.
    pub degraded: u64,
    /// Cumulative write cost of the admin plane.
    pub write: WriteCostSnapshot,
    /// Full queue/exec/total histograms behind the percentile fields.
    /// Present on snapshots taken from a live [`Metrics`] (and on wire
    /// snapshots whose peer shipped them); `None` only for legacy sources,
    /// which then aggregate with the worst-shard fallback.
    pub lat: Option<LatencyHists>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Fresh counters, empty histograms.
    pub fn new() -> Self {
        let h = latency_histogram;
        Metrics {
            counters: TrackedMutex::new(
                &METRICS_COUNTERS,
                Inner {
                    submitted: 0,
                    completed: 0,
                    rejected_busy: 0,
                    batches: 0,
                    batch_sizes: Vec::new(),
                    queue_us: h(),
                    exec_us: h(),
                    total_us: h(),
                    per_k: BTreeMap::new(),
                    kinds: [
                        KindLane { completed: 0, truncated: 0, total_us: h() },
                        KindLane { completed: 0, truncated: 0, total_us: h() },
                    ],
                    admin: [
                        AdminLane { completed: 0, total_us: h() },
                        AdminLane { completed: 0, total_us: h() },
                        AdminLane { completed: 0, total_us: h() },
                    ],
                    admin_rejected: 0,
                    degraded: 0,
                    write_cells: 0,
                    write_pulses: 0,
                    write_energy_j: 0.0,
                    write_latency_s: 0.0,
                },
            ),
        }
    }

    /// Record a request accepted into the queue.
    pub fn on_submit(&self) {
        self.counters.lock().submitted += 1;
    }

    /// Record a request rejected with `busy` backpressure.
    pub fn on_reject_busy(&self) {
        self.counters.lock().rejected_busy += 1;
    }

    /// Record one formed batch of `size` requests.
    pub fn on_batch(&self, size: usize) {
        let mut g = self.counters.lock();
        g.batches += 1;
        g.batch_sizes.push(size as u64);
    }

    /// Record one completed top-k search with its queue/exec split.
    pub fn on_complete(&self, queued: Duration, exec: Duration, k: usize) {
        let mut g = self.counters.lock();
        let tot = Self::record_shared(&mut g, queued, exec);
        let lane = g
            .per_k
            .entry(k_lane(k))
            .or_insert_with(|| KLane { completed: 0, total_us: latency_histogram() });
        lane.completed += 1;
        lane.total_us.record(tot);
        let kind = &mut g.kinds[SearchKind::TopK.idx()];
        kind.completed += 1;
        kind.total_us.record(tot);
    }

    /// Record one completed threshold search: same queue/exec accounting as
    /// top-k, but landing in the threshold kind lane (no per-k lane — a
    /// threshold query has no k) with its spill flag counted.
    pub fn on_complete_threshold(&self, queued: Duration, exec: Duration, truncated: bool) {
        let mut g = self.counters.lock();
        let tot = Self::record_shared(&mut g, queued, exec);
        let kind = &mut g.kinds[SearchKind::Threshold.idx()];
        kind.completed += 1;
        if truncated {
            kind.truncated += 1;
        }
        kind.total_us.record(tot);
    }

    /// Shared completion accounting (global counters + the three latency
    /// histograms); returns the clamped total in µs for the caller's lane.
    fn record_shared(g: &mut Inner, queued: Duration, exec: Duration) -> f64 {
        g.completed += 1;
        let qu = queued.as_secs_f64() * 1e6;
        let ex = exec.as_secs_f64() * 1e6;
        g.queue_us.record(qu.max(0.5));
        g.exec_us.record(ex.max(0.5));
        let tot = (qu + ex).max(0.5);
        g.total_us.record(tot);
        tot
    }

    /// Record one committed admin op with its wall time and (for ops that
    /// programmed the array) the write-verify cost report.
    pub fn on_admin(&self, kind: AdminKind, total: Duration, report: Option<&WriteReport>) {
        let mut g = self.counters.lock();
        let lane = &mut g.admin[kind.idx()];
        lane.completed += 1;
        lane.total_us.record((total.as_secs_f64() * 1e6).max(0.5));
        if let Some(r) = report {
            g.absorb_write(r);
        }
    }

    /// Account write pulses that were spent even though the op was rejected
    /// (verify failure): the array fired them regardless.
    pub fn on_write_spent(&self, report: &WriteReport) {
        self.counters.lock().absorb_write(report);
    }

    /// Record a rejected admin op (bad row, dims mismatch, verify failure).
    pub fn on_admin_rejected(&self) {
        self.counters.lock().admin_rejected += 1;
    }

    /// Record a scatter-gather batch served without one or more unhealthy
    /// shards (the responses carried the typed partial flag).
    pub fn on_degraded(&self) {
        self.counters.lock().degraded += 1;
    }

    /// Consistent point-in-time copy of every counter and histogram.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.counters.lock();
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.iter().sum::<u64>() as f64 / g.batch_sizes.len() as f64
        };
        MetricsSnapshot {
            submitted: g.submitted,
            completed: g.completed,
            rejected_busy: g.rejected_busy,
            batches: g.batches,
            mean_batch_size: mean_batch,
            queue_p50_us: g.queue_us.quantile(0.5),
            queue_p99_us: g.queue_us.quantile(0.99),
            exec_p50_us: g.exec_us.quantile(0.5),
            exec_p99_us: g.exec_us.quantile(0.99),
            total_p50_us: g.total_us.quantile(0.5),
            total_p99_us: g.total_us.quantile(0.99),
            total_mean_us: g.total_us.mean(),
            per_k: g
                .per_k
                .iter()
                .map(|(&k, lane)| PerKSnapshot {
                    k,
                    completed: lane.completed,
                    total_p50_us: lane.total_us.quantile(0.5),
                    total_p99_us: lane.total_us.quantile(0.99),
                    hist: Some(lane.total_us.clone()),
                })
                .collect(),
            kinds: SearchKind::ALL
                .iter()
                .filter(|kind| g.kinds[kind.idx()].completed > 0)
                .map(|kind| {
                    let lane = &g.kinds[kind.idx()];
                    KindLaneSnapshot {
                        kind: kind.name(),
                        completed: lane.completed,
                        truncated: lane.truncated,
                        total_p50_us: lane.total_us.quantile(0.5),
                        total_p99_us: lane.total_us.quantile(0.99),
                        hist: Some(lane.total_us.clone()),
                    }
                })
                .collect(),
            admin: AdminKind::ALL
                .iter()
                .filter(|kind| g.admin[kind.idx()].completed > 0)
                .map(|kind| {
                    let lane = &g.admin[kind.idx()];
                    AdminLaneSnapshot {
                        kind: kind.name(),
                        completed: lane.completed,
                        total_p50_us: lane.total_us.quantile(0.5),
                        total_p99_us: lane.total_us.quantile(0.99),
                        hist: Some(lane.total_us.clone()),
                    }
                })
                .collect(),
            admin_rejected: g.admin_rejected,
            degraded: g.degraded,
            write: WriteCostSnapshot {
                cells: g.write_cells,
                pulses: g.write_pulses,
                energy_j: g.write_energy_j,
                latency_s: g.write_latency_s,
            },
            lat: Some(LatencyHists {
                queue_us: g.queue_us.clone(),
                exec_us: g.exec_us.clone(),
                total_us: g.total_us.clone(),
            }),
        }
    }
}

impl MetricsSnapshot {
    /// Human-readable report block.
    pub fn report(&self) -> String {
        let mut out = format!(
            "requests: submitted={} completed={} rejected(busy)={}\n\
             batches: {} (mean size {:.1})\n\
             queue  µs: p50={:.1} p99={:.1}\n\
             exec   µs: p50={:.1} p99={:.1}\n\
             total  µs: p50={:.1} p99={:.1} mean={:.1}",
            self.submitted,
            self.completed,
            self.rejected_busy,
            self.batches,
            self.mean_batch_size,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.total_p50_us,
            self.total_p99_us,
            self.total_mean_us,
        );
        for lane in &self.per_k {
            out.push_str(&format!(
                "\n  k={:<4} n={:<8} total µs: p50={:.1} p99={:.1}",
                lane.k, lane.completed, lane.total_p50_us, lane.total_p99_us
            ));
        }
        for lane in &self.kinds {
            out.push_str(&format!(
                "\n  kind {:<9} n={:<6} truncated={:<6} total µs: p50={:.1} p99={:.1}",
                lane.kind, lane.completed, lane.truncated, lane.total_p50_us, lane.total_p99_us
            ));
        }
        for lane in &self.admin {
            out.push_str(&format!(
                "\n  admin {:<7} n={:<6} total µs: p50={:.1} p99={:.1}",
                lane.kind, lane.completed, lane.total_p50_us, lane.total_p99_us
            ));
        }
        if !self.admin.is_empty() || self.admin_rejected > 0 {
            out.push_str(&format!(
                "\n  writes: {} cells / {} pulses, {:.2} nJ, {:.1} µs array time, {} rejected",
                self.write.cells,
                self.write.pulses,
                self.write.energy_j * 1e9,
                self.write.latency_s * 1e6,
                self.admin_rejected
            ));
        }
        if self.degraded > 0 {
            out.push_str(&format!(
                "\n  degraded: {} scatter batches served with shards missing",
                self.degraded
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject_busy();
        m.on_batch(8);
        m.on_batch(4);
        m.on_complete(Duration::from_micros(100), Duration::from_micros(50), 1);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected_busy, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.completed, 1);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-9);
        assert!(s.total_p50_us >= 100.0);
        assert_eq!(s.degraded, 0);
        m.on_degraded();
        let s = m.snapshot();
        assert_eq!(s.degraded, 1);
        assert!(s.report().contains("degraded: 1"));
    }

    #[test]
    fn per_k_lanes_split_latency() {
        let m = Metrics::new();
        m.on_complete(Duration::from_micros(10), Duration::from_micros(10), 1);
        m.on_complete(Duration::from_micros(10), Duration::from_micros(10), 1);
        m.on_complete(Duration::from_micros(500), Duration::from_micros(500), 8);
        let s = m.snapshot();
        assert_eq!(s.per_k.len(), 2);
        assert_eq!(s.per_k[0].k, 1);
        assert_eq!(s.per_k[0].completed, 2);
        assert_eq!(s.per_k[1].k, 8);
        assert_eq!(s.per_k[1].completed, 1);
        assert!(
            s.per_k[1].total_p50_us > s.per_k[0].total_p50_us,
            "deep-k lane must show its higher latency"
        );
    }

    #[test]
    fn large_k_values_share_bounded_lanes() {
        let m = Metrics::new();
        for k in [17usize, 25, 32, 1000, 1 << 40] {
            m.on_complete(Duration::from_micros(10), Duration::from_micros(10), k);
        }
        let s = m.snapshot();
        let keys: Vec<usize> = s.per_k.iter().map(|l| l.k).collect();
        assert_eq!(keys, vec![32, 1024, 1 << 40], "power-of-two lanes above 16");
        assert_eq!(s.per_k[0].completed, 3, "17, 25 and 32 share the 32 lane");
        // Absurd k must not overflow the lane computation.
        m.on_complete(Duration::from_micros(1), Duration::from_micros(1), usize::MAX - 1);
        assert!(m.snapshot().per_k.iter().any(|l| l.k == usize::MAX));
    }

    /// Top-k and threshold completions split into their own kind lanes;
    /// only the threshold lane counts truncated responses.
    #[test]
    fn kind_lanes_split_completions() {
        let m = Metrics::new();
        assert!(m.snapshot().kinds.is_empty(), "no lanes before any completion");
        m.on_complete(Duration::from_micros(10), Duration::from_micros(10), 2);
        m.on_complete_threshold(Duration::from_micros(20), Duration::from_micros(20), false);
        m.on_complete_threshold(Duration::from_micros(20), Duration::from_micros(20), true);
        let s = m.snapshot();
        assert_eq!(s.completed, 3, "kind lanes share the global counter");
        assert_eq!(s.kinds.len(), 2);
        assert_eq!(s.kinds[0].kind, "topk");
        assert_eq!(s.kinds[0].completed, 1);
        assert_eq!(s.kinds[0].truncated, 0);
        assert_eq!(s.kinds[1].kind, "threshold");
        assert_eq!(s.kinds[1].completed, 2);
        assert_eq!(s.kinds[1].truncated, 1);
        let text = s.report();
        assert!(text.contains("kind threshold"), "{text}");
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.on_submit();
        m.on_complete(Duration::from_micros(10), Duration::from_micros(5), 3);
        let text = m.snapshot().report();
        assert!(text.contains("submitted=1"));
        assert!(text.contains("total"));
        assert!(text.contains("k=3"), "{text}");
    }

    #[test]
    fn admin_lanes_accumulate_write_costs() {
        let m = Metrics::new();
        assert!(m.snapshot().admin.is_empty(), "no lanes before any admin op");
        let report = WriteReport {
            cells: 64,
            pulses: 100,
            failures: 0,
            energy: 1e-13,
            latency: 3e-6,
            round_latencies: vec![1e-6, 2e-6],
        };
        m.on_admin(AdminKind::Update, Duration::from_micros(40), Some(&report));
        m.on_admin(AdminKind::Update, Duration::from_micros(60), Some(&report));
        m.on_admin(AdminKind::Delete, Duration::from_micros(5), None);
        m.on_admin_rejected();
        let s = m.snapshot();
        assert_eq!(s.admin.len(), 2, "only active lanes reported");
        assert_eq!(s.admin[0].kind, "update");
        assert_eq!(s.admin[0].completed, 2);
        assert_eq!(s.admin[1].kind, "delete");
        assert_eq!(s.admin[1].completed, 1);
        assert_eq!(s.admin_rejected, 1);
        assert_eq!(s.write.cells, 128);
        assert_eq!(s.write.pulses, 200);
        assert!((s.write.energy_j - 2e-13).abs() < 1e-25);
        assert!((s.write.latency_s - 6e-6).abs() < 1e-15);
        let text = s.report();
        assert!(text.contains("admin update"), "{text}");
        assert!(text.contains("writes:"), "{text}");
    }
}
