//! Serving metrics: counters and latency histograms for the queue, the
//! engine execution, and end-to-end request time.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::Histogram;

struct Inner {
    submitted: u64,
    completed: u64,
    rejected_busy: u64,
    batches: u64,
    batch_sizes: Vec<u64>,
    queue_us: Histogram,
    exec_us: Histogram,
    total_us: Histogram,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected_busy: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub total_p50_us: f64,
    pub total_p99_us: f64,
    pub total_mean_us: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        let h = || Histogram::log_spaced(0.5, 10_000_000.0, 120);
        Metrics {
            inner: Mutex::new(Inner {
                submitted: 0,
                completed: 0,
                rejected_busy: 0,
                batches: 0,
                batch_sizes: Vec::new(),
                queue_us: h(),
                exec_us: h(),
                total_us: h(),
            }),
        }
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_reject_busy(&self) {
        self.inner.lock().unwrap().rejected_busy += 1;
    }

    pub fn on_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_sizes.push(size as u64);
    }

    pub fn on_complete(&self, queued: Duration, exec: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        let qu = queued.as_secs_f64() * 1e6;
        let ex = exec.as_secs_f64() * 1e6;
        g.queue_us.record(qu.max(0.5));
        g.exec_us.record(ex.max(0.5));
        g.total_us.record((qu + ex).max(0.5));
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.iter().sum::<u64>() as f64 / g.batch_sizes.len() as f64
        };
        MetricsSnapshot {
            submitted: g.submitted,
            completed: g.completed,
            rejected_busy: g.rejected_busy,
            batches: g.batches,
            mean_batch_size: mean_batch,
            queue_p50_us: g.queue_us.quantile(0.5),
            queue_p99_us: g.queue_us.quantile(0.99),
            exec_p50_us: g.exec_us.quantile(0.5),
            exec_p99_us: g.exec_us.quantile(0.99),
            total_p50_us: g.total_us.quantile(0.5),
            total_p99_us: g.total_us.quantile(0.99),
            total_mean_us: g.total_us.mean(),
        }
    }
}

impl MetricsSnapshot {
    /// Human-readable report block.
    pub fn report(&self) -> String {
        format!(
            "requests: submitted={} completed={} rejected(busy)={}\n\
             batches: {} (mean size {:.1})\n\
             queue  µs: p50={:.1} p99={:.1}\n\
             exec   µs: p50={:.1} p99={:.1}\n\
             total  µs: p50={:.1} p99={:.1} mean={:.1}",
            self.submitted,
            self.completed,
            self.rejected_busy,
            self.batches,
            self.mean_batch_size,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.total_p50_us,
            self.total_p99_us,
            self.total_mean_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject_busy();
        m.on_batch(8);
        m.on_batch(4);
        m.on_complete(Duration::from_micros(100), Duration::from_micros(50));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected_busy, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.completed, 1);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-9);
        assert!(s.total_p50_us >= 100.0);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.on_submit();
        m.on_complete(Duration::from_micros(10), Duration::from_micros(5));
        let text = m.snapshot().report();
        assert!(text.contains("submitted=1"));
        assert!(text.contains("total"));
    }
}
