//! L3 coordinator: the AM *serving engine* around the COSIME tiles.
//!
//! The paper's system contribution is an inference-accelerating associative
//! memory; the coordinator is the machinery a deployment needs around it
//! (vLLM-router-shaped):
//!
//! * [`request`] — request/response types (requests carry a top-k depth,
//!   responses carry the ranked winners and the serving epoch), the admin
//!   ops ([`request::AdminOp`]) and submit errors.
//! * [`tiles`] — [`tiles::TileManager`]: shards stored words across
//!   fixed-geometry COSIME tiles and merges per-tile top-k selectors
//!   (hierarchical WTA — exactly how multiple physical arrays compose,
//!   §3.5), parallelized over tile×batch work slots with reused buffers.
//!   Live-updatable with epoch/generation coherence: mutations commit under
//!   a write lock while in-flight batches score one consistent snapshot.
//! * [`batcher`] — dynamic batching queue (size + deadline policy) with
//!   bounded-depth backpressure.
//! * [`service`] — [`service::AmService`]: worker threads draining the
//!   batcher into the tile manager's block kernel with worker-lifetime
//!   buffers (zero per-query allocations); per-request timing; the admin
//!   plane ([`service::AmService::admin`]) applying write-verified class
//!   updates; graceful shutdown.
//! * [`metrics`] — counters + latency histograms (queue/execute/total),
//!   broken down per requested k, plus admin lanes with cumulative
//!   write-verify cost (pulses, energy, array time). Histogram buckets are
//!   log-spaced and aligned across lanes, so cross-shard aggregation merges
//!   them exactly ([`crate::util::Histogram::merge_from`]).
//! * [`backend`] — the [`backend::Backend`] trait: one transport-agnostic,
//!   completion-based serving surface (`submit_search` → [`backend::Ticket`]
//!   → poll) that local stacks ([`backend::LocalBackend`]), shard routers
//!   ([`crate::server::RouterBackend`]) and remote connections
//!   ([`crate::server::RemoteBackend`]) all implement — the seam the TCP
//!   frontend serves from.
//!
//! Engines are pluggable ([`crate::am::AmEngine`]): digital (bit-exact),
//! XLA (compiled Pallas artifact), analog (circuit-sim), or the baselines.

/// The completion-based `Backend` trait and its local implementation.
pub mod backend;
/// Lock-and-condvar batching queue.
pub mod batcher;
/// Request/response types and typed submit errors.
pub mod metrics;
/// The batching search service: worker loop + admin plane.
pub mod request;
/// Tile manager: sharded storage with epoch-guarded mutation.
pub mod service;
/// Tile manager: epoch-guarded sharded storage and block search.
pub mod tiles;

pub use backend::{
    AdminCmd, AdminOutcome, Backend, BackendHealth, BatchResult, CatchupBatch, CatchupEntry, Hit,
    LocalBackend, SnapshotChunk, Ticket, WriteCost,
};
pub use batcher::Batcher;
pub use metrics::{
    latency_histogram, AdminKind, AdminLaneSnapshot, LatencyHists, Metrics, MetricsSnapshot,
    PerKSnapshot, WriteCostSnapshot,
};
pub use request::{AdminOp, AdminResponse, RequestTiming, SearchResponse, SubmitError};
pub use service::AmService;
pub use tiles::{Commit, EpochMismatch, TileFactory, TileManager, TileScratch};
