//! L3 coordinator: the AM *serving engine* around the COSIME tiles.
//!
//! The paper's system contribution is an inference-accelerating associative
//! memory; the coordinator is the machinery a deployment needs around it
//! (vLLM-router-shaped):
//!
//! * [`request`] — request/response types (requests carry a top-k depth,
//!   responses carry the ranked winners) and submit errors.
//! * [`tiles`] — [`tiles::TileManager`]: shards stored words across
//!   fixed-geometry COSIME tiles and merges per-tile top-k selectors
//!   (hierarchical WTA — exactly how multiple physical arrays compose,
//!   §3.5), parallelized over tile×batch work slots with reused buffers.
//! * [`batcher`] — dynamic batching queue (size + deadline policy) with
//!   bounded-depth backpressure.
//! * [`service`] — [`service::AmService`]: worker threads draining the
//!   batcher into the tile manager's block kernel with worker-lifetime
//!   buffers (zero per-query allocations); per-request timing; graceful
//!   shutdown.
//! * [`metrics`] — counters + latency histograms (queue/execute/total),
//!   broken down per requested k.
//!
//! Engines are pluggable ([`crate::am::AmEngine`]): digital (bit-exact),
//! XLA (compiled Pallas artifact), analog (circuit-sim), or the baselines.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod service;
pub mod tiles;

pub use batcher::Batcher;
pub use metrics::{Metrics, MetricsSnapshot, PerKSnapshot};
pub use request::{RequestTiming, SearchResponse, SubmitError};
pub use service::AmService;
pub use tiles::{TileManager, TileScratch};
