//! L3 coordinator: the AM *serving engine* around the COSIME tiles.
//!
//! The paper's system contribution is an inference-accelerating associative
//! memory; the coordinator is the machinery a deployment needs around it
//! (vLLM-router-shaped):
//!
//! * [`request`] — request/response types and submit errors.
//! * [`tiles`] — [`tiles::TileManager`]: shards stored words across
//!   fixed-geometry COSIME tiles and merges per-tile winners (hierarchical
//!   WTA — exactly how multiple physical arrays compose, §3.5).
//! * [`batcher`] — dynamic batching queue (size + deadline policy) with
//!   bounded-depth backpressure.
//! * [`service`] — [`service::AmService`]: worker threads draining the
//!   batcher into the tile manager; per-request timing; graceful shutdown.
//! * [`metrics`] — counters + latency histograms (queue/execute/total).
//!
//! Engines are pluggable ([`crate::am::AmEngine`]): digital (bit-exact),
//! XLA (compiled Pallas artifact), analog (circuit-sim), or the baselines.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod service;
pub mod tiles;

pub use batcher::Batcher;
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{RequestTiming, SearchResponse, SubmitError};
pub use service::AmService;
pub use tiles::TileManager;
