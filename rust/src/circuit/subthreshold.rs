//! Subthreshold (weak inversion) MOS law — paper Eq. 3/Eq. 5:
//! `I_DS ≈ I_0 (W/L) exp(V_GS / ηV_T)` and its inverse.

use crate::config::consts;

/// Drain-source current of a subthreshold MOS (paper Eq. 3).
/// Exponent is clamped to keep the behavioral solver finite when a node
/// briefly overshoots during transients.
pub fn ids_subthreshold(i0_wl: f64, v_gs: f64, eta: f64) -> f64 {
    let x = (v_gs / (eta * consts::V_T)).clamp(-80.0, 80.0);
    i0_wl * x.exp()
}

/// Gate-source voltage required for a target subthreshold current
/// (paper Eq. 5: `V_GS = ηV_T ln(I_DS/I_0)`).
pub fn vgs_for_current(i0_wl: f64, i_ds: f64, eta: f64) -> f64 {
    assert!(i_ds > 0.0 && i0_wl > 0.0, "currents must be positive");
    eta * consts::V_T * (i_ds / i0_wl).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn law_and_inverse_roundtrip() {
        let (i0, eta) = (1e-7, 1.35);
        for &i in &[1e-9, 5e-8, 3e-7, 2e-6] {
            let v = vgs_for_current(i0, i, eta);
            let back = ids_subthreshold(i0, v, eta);
            assert!((back - i).abs() / i < 1e-9, "{i} -> {back}");
        }
    }

    #[test]
    fn exponential_slope_is_eta_vt_per_e_fold() {
        let (i0, eta) = (1e-7, 1.4);
        let i1 = ids_subthreshold(i0, 0.2, eta);
        let i2 = ids_subthreshold(i0, 0.2 + eta * crate::config::consts::V_T, eta);
        assert!((i2 / i1 - std::f64::consts::E).abs() < 1e-9);
    }

    #[test]
    fn clamp_prevents_overflow() {
        let i = ids_subthreshold(1e-7, 100.0, 1.0);
        assert!(i.is_finite());
    }
}
