//! Lazzaro O(N) current-mode winner-take-all network (paper §3.4–3.5,
//! Fig. 3c) with excitatory feedback mirrors [22][23].
//!
//! Topology per rail i: input current I_zi charges node V_i; sourcing
//! transistor T1_i (gate on the common rail V_c) sinks the node; output
//! transistor T2_i (gate V_i, source V_c) carries
//! `I_oi = I_o·exp((V_i−V_c)/ηV_T)` (paper Eq. 10); all I_oi sum into the
//! common rail against the bias sink I_c, so ΣI_oi = I_c at equilibrium and
//! the rail with the largest input ends up carrying ≈ all of I_c. The
//! feedback mirror (T3/T4) returns β·I_oi to node i, sharpening the decision.
//!
//! Solvers:
//! * [`Wta::settle`] — transient integration (explicit Euler with a
//!   thermal-voltage slew clamp; the common rail is treated as the fast
//!   algebraic constraint ΣI_oi = I_c, which is exact for C_c → 0). Yields
//!   the *search delay* the paper measures plus full waveforms (Fig. 4b).
//! * [`WtaInstance::winner_static`] — operating-point winner with frozen
//!   input offsets, used by the fast Monte Carlo path (Fig. 7).

use crate::config::{consts, WtaConfig};
use crate::util::Rng;

use super::waveform::Waveform;
use crate::device::VariationSampler;

/// Nominal WTA block.
#[derive(Debug, Clone)]
pub struct Wta {
    /// Design parameters.
    pub cfg: WtaConfig,
}

/// A fabricated WTA instance: frozen per-rail input-referred offsets.
#[derive(Debug, Clone)]
pub struct WtaInstance {
    /// Design parameters.
    pub cfg: WtaConfig,
    /// Multiplicative input-referred error per rail (mirror + T1/T2 mismatch).
    pub rail_gain: Vec<f64>,
}

/// Result of a transient settle.
#[derive(Debug, Clone)]
pub struct WtaOutcome {
    /// Winning rail index (output current crossed the win threshold).
    pub winner: usize,
    /// Time from activation to decision (s). `t_max` if never settled.
    pub latency: f64,
    /// Whether the separation criterion was actually met before `t_max`.
    pub settled: bool,
    /// Time-averaged total supply current during the search (A) — feeds the
    /// energy model (bias + output branches + feedback mirrors).
    pub avg_supply_current: f64,
    /// Optional waveform capture: per-rail output currents (Fig. 4b).
    pub waveform: Option<Waveform>,
}

impl Wta {
    /// Nominal block with the given parameters.
    pub fn new(cfg: WtaConfig) -> Self {
        Wta { cfg }
    }

    /// Output-transistor prefactor I_o (A): sized so that a rail carrying the
    /// full bias sits at a comfortable subthreshold V_GS.
    fn i_o(&self) -> f64 {
        1e-7
    }

    /// Sourcing-transistor prefactor I_s (A).
    fn i_s(&self) -> f64 {
        1e-9
    }

    /// Total bias current for an M-rail instance: the common-rail source is
    /// sized with the array (one share per branch), which keeps the initial
    /// per-rail output current — and with it the regenerative feedback
    /// strength and settle latency — independent of M (§3.5), while total
    /// WTA supply current grows linearly with rails (Fig. 6a energy trend).
    fn i_c(&self, rails: usize) -> f64 {
        self.cfg.i_bias * rails as f64
    }

    /// Solve the common-rail voltage from the algebraic constraint
    /// ΣI_oi = I_c given node voltages (log-sum-exp, numerically safe).
    fn solve_vc(&self, v: &[f64]) -> f64 {
        let n_vt = self.cfg.eta * consts::V_T;
        let vmax = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let sum: f64 = v.iter().map(|&vi| ((vi - vmax) / n_vt).exp()).sum();
        // I_o · exp((V_i - V_c)/nVT) summed = I_c
        vmax + n_vt * (self.i_o() * sum / self.i_c(v.len())).ln()
    }

    /// Transient settle. `inputs` are the rail input currents (A), applied as
    /// a step at t = 0 (the paper activates the WTA only after the
    /// translinear outputs are stable — Fig. 4b note). If `capture` is set,
    /// waveforms of every rail's output current are recorded.
    ///
    /// Decision criterion: the winning rail's output current must exceed the
    /// runner-up's by `win_separation`× and persist. This matches the paper's
    /// scalability argument (§3.5): the *differential* dynamics dV₁/dI_z1 are
    /// M-independent up to an (M−1)/M factor (Eq. 13–14), so the separation
    /// latency is near-flat in the number of rails — unlike an
    /// absolute-current criterion, which would pick up a log(M) term.
    pub fn settle(&self, inputs: &[f64], capture: bool) -> WtaOutcome {
        let c = &self.cfg;
        let m = inputs.len();
        assert!(m >= 2, "WTA needs at least two rails");
        let n_vt = c.eta * consts::V_T;
        let (i_o, i_s) = (self.i_o(), self.i_s());

        // Node voltages start discharged.
        let mut v = vec![0.0f64; m];
        let steps = (c.t_max / c.dt).ceil() as usize;
        let capture_stride = (steps / 4000).max(1);
        let mut wf = capture.then(|| {
            let names: Vec<String> =
                (0..m).map(|i| format!("i_out_{i}")).chain(std::iter::once("v_c".into())).collect();
            Waveform::new(c.dt * capture_stride as f64, &names)
        });

        let slew_clamp = n_vt; // max |ΔV| per step: one thermal voltage
        let i_c = self.i_c(m);
        let i_in_sum: f64 = inputs.iter().sum();
        let mut supply_integral = 0.0f64;
        let mut elapsed = 0.0f64;
        let mut winner = 0usize;
        let mut settled_at: Option<f64> = None;
        let mut hold = 0usize;
        let hold_needed = 8; // decision must persist to count as settled

        for step in 0..steps {
            let v_c = self.solve_vc(&v);
            // Output currents (paper Eq. 10).
            let i_out: Vec<f64> =
                v.iter().map(|&vi| i_o * (((vi - v_c) / n_vt).clamp(-80.0, 80.0)).exp()).collect();
            let i_out_sum: f64 = i_out.iter().sum();

            // Supply accounting: bias sink + output branches + feedback
            // mirrors + input branches (two mirror legs each, §4.1).
            supply_integral +=
                (i_c + i_out_sum + 2.0 * c.feedback_gain * i_out_sum + 2.0 * i_in_sum) * c.dt;
            elapsed = (step + 1) as f64 * c.dt;

            if let Some(w) = wf.as_mut() {
                if step % capture_stride == 0 {
                    let mut row = i_out.clone();
                    row.push(v_c);
                    w.push(&row);
                }
            }

            // Decision check: winner separated from runner-up.
            let (argmax, imax) = i_out
                .iter()
                .enumerate()
                .map(|(i, &x)| (i, x))
                .max_by(|a, b| a.1.partial_cmp(&b.1).expect("finite currents"))
                .expect("nonempty");
            let second = i_out
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != argmax)
                .map(|(_, &x)| x)
                .fold(f64::NEG_INFINITY, f64::max);
            // Absolute floor: 1.5× the per-rail equal share, M-independent.
            if imax >= c.win_separation * second && imax > 1.5 * c.i_bias {
                if hold == 0 || argmax == winner {
                    hold += 1;
                } else {
                    hold = 1;
                }
                winner = argmax;
                if hold >= hold_needed && settled_at.is_none() {
                    settled_at = Some(elapsed);
                    if !capture {
                        break; // waveform runs record the full window
                    }
                }
            } else {
                hold = 0;
            }

            // Rail ODEs: C_v dV_i/dt = I_zi + β·I_oi − I_1i.
            // T1 sink: gate V_c, Early-effect dependence on the drain V_i.
            for i in 0..m {
                let i_sink = i_s
                    * ((v_c / n_vt).clamp(-80.0, 80.0)).exp()
                    * (1.0 + v[i].max(0.0) / c.early_voltage);
                let net = inputs[i] + c.feedback_gain * i_out[i] - i_sink;
                let dv = (net / c.c_node * c.dt).clamp(-slew_clamp, slew_clamp);
                v[i] = (v[i] + dv).clamp(-0.2, c.vdd);
            }
        }

        let latency = settled_at.unwrap_or(c.t_max);
        let avg_supply = supply_integral / elapsed.max(c.dt);
        WtaOutcome {
            winner,
            latency,
            settled: settled_at.is_some(),
            avg_supply_current: avg_supply,
            waveform: wf,
        }
    }

    /// Fabricate an instance with frozen per-rail mismatch.
    pub fn instance(&self, rails: usize, sampler: &VariationSampler, rng: &mut Rng) -> WtaInstance {
        // Rail mismatch is input-referred: the paper's WTA resolves ≈1 %
        // current differences, so the offset scale is a ~1 % multiplicative
        // error plus the supply variation common factor folded per-rail.
        let sigma = self.cfg.sigma_offset_rel;
        let rail_gain = (0..rails)
            .map(|_| {
                let g = sampler.stage_gain(rng);
                // Compress the full mirror-stage spread down to the WTA's
                // input-referred resolution floor.
                1.0 + sigma * (g - 1.0) / 0.15_f64.max(1e-9)
            })
            .collect();
        WtaInstance { cfg: self.cfg.clone(), rail_gain }
    }

    /// Ideal instance (no mismatch).
    pub fn ideal_instance(&self, rails: usize) -> WtaInstance {
        WtaInstance { cfg: self.cfg.clone(), rail_gain: vec![1.0; rails] }
    }
}

impl WtaInstance {
    /// Operating-point winner: argmax of mismatched effective inputs (ties
    /// break to the lowest rail). Matches the transient solver's decision
    /// for inputs within the WTA's resolving range but runs in O(M).
    pub fn winner_static(&self, inputs: &[f64]) -> usize {
        assert_eq!(inputs.len(), self.rail_gain.len(), "rail count mismatch");
        let (mut winner, mut best) = (0usize, f64::NEG_INFINITY);
        for (i, (&x, &g)) in inputs.iter().zip(&self.rail_gain).enumerate() {
            let v = x * g;
            if v > best {
                winner = i;
                best = v;
            }
        }
        winner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CosimeConfig, WtaConfig};
    use crate::util::rng;

    fn wta() -> Wta {
        Wta::new(WtaConfig::default())
    }

    #[test]
    fn picks_clear_winner() {
        let w = wta();
        let mut inputs = vec![0.3e-6; 8];
        inputs[5] = 0.9e-6;
        let out = w.settle(&inputs, false);
        assert!(out.settled, "must settle");
        assert_eq!(out.winner, 5);
        assert!(out.latency < w.cfg.t_max / 2.0);
    }

    #[test]
    fn resolves_worst_case_pair() {
        // Paper's worst case: squared cosines 1/4 vs 1/5 → 25 % relative gap.
        let w = wta();
        let scale = 1.2e-6;
        let inputs = vec![scale * 0.25, scale * 0.20];
        let out = w.settle(&inputs, false);
        assert!(out.settled);
        assert_eq!(out.winner, 0);
    }

    #[test]
    fn resolves_one_percent_difference() {
        // Paper §3.4: "can distinguish input currents with even 1 % difference".
        let w = wta();
        let inputs = vec![1.0e-6, 1.01e-6, 0.99e-6, 1.0e-6];
        let out = w.settle(&inputs, false);
        assert!(out.settled);
        assert_eq!(out.winner, 1);
    }

    #[test]
    fn latency_weakly_dependent_on_rail_count() {
        // Paper §3.5 / Fig. 6a: latency ≈ flat as rails scale.
        let w = wta();
        let lat = |m: usize| {
            let mut inputs = vec![0.20e-6 * 1.2; m];
            inputs[m / 2] = 0.25e-6 * 1.2;
            let o = w.settle(&inputs, false);
            assert!(o.settled, "m={m}");
            o.latency
        };
        let l16 = lat(16);
        let l256 = lat(256);
        assert!(
            l256 / l16 < 2.0,
            "latency must be near-flat in rails: {l16:.2e} vs {l256:.2e}"
        );
    }

    #[test]
    fn waveform_capture_shapes() {
        let w = wta();
        let out = w.settle(&[0.3e-6, 0.5e-6, 0.2e-6], true);
        let wf = out.waveform.expect("capture requested");
        assert_eq!(wf.traces.len(), 4); // 3 rails + v_c
        assert!(wf.len() > 10);
        // Winner's final output current dominates.
        let last = wf.traces[1].values.last().copied().unwrap();
        let other = wf.traces[0].values.last().copied().unwrap();
        assert!(last > 5.0 * other);
    }

    #[test]
    fn static_winner_matches_transient_for_resolved_gaps() {
        let cfg = CosimeConfig::default();
        let w = wta();
        let inst = w.ideal_instance(6);
        let mut r = rng(9);
        for _ in 0..20 {
            let inputs: Vec<f64> = (0..6).map(|_| 0.2e-6 + 1.0e-6 * r.f64()).collect();
            let stat = inst.winner_static(&inputs);
            let tran = w.settle(&inputs, false);
            if tran.settled {
                assert_eq!(stat, tran.winner, "inputs {inputs:?}");
            }
        }
        let _ = cfg;
    }

    #[test]
    fn instance_mismatch_can_flip_tiny_gaps() {
        // With ~1 % input-referred offsets, a 0.1 % gap is below resolution:
        // across many fabricated instances the "wrong" rail must win sometimes.
        let cfg = CosimeConfig::default();
        let sampler = crate::device::VariationSampler::new(&cfg);
        let w = wta();
        let mut r = rng(10);
        let inputs = vec![1.000e-6, 1.001e-6];
        let mut wrong = 0;
        for _ in 0..200 {
            let inst = w.instance(2, &sampler, &mut r);
            if inst.winner_static(&inputs) != 1 {
                wrong += 1;
            }
        }
        assert!(wrong > 10, "sub-resolution gap should flip sometimes: {wrong}");
        assert!(wrong < 190, "but not always: {wrong}");
    }

    #[test]
    fn energy_scales_with_rail_count() {
        // Fig. 6a: search energy grows with the number of rails (more input
        // and output branches driven by the supply).
        let w = wta();
        let sup = |m: usize| {
            let mut inputs = vec![0.24e-6; m];
            inputs[0] = 0.3e-6;
            w.settle(&inputs, false).avg_supply_current
        };
        let s8 = sup(8);
        let s64 = sup(64);
        assert!(s64 > 3.0 * s8, "supply current must grow with rails: {s8:.2e} vs {s64:.2e}");
    }
}
