//! Waveform capture for transient simulations — backs the Fig. 4b and
//! Fig. 7a plots and the CSV dumps under `results/`.

/// One named signal over time.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Signal name.
    pub name: String,
    /// Sample values, one per stored timestep.
    pub values: Vec<f64>,
}

/// A set of equally-sampled traces sharing a time base.
#[derive(Debug, Clone)]
pub struct Waveform {
    /// Time between stored samples (s).
    pub dt: f64,
    /// The traces, in construction order.
    pub traces: Vec<Trace>,
}

impl Waveform {
    /// Empty waveform for the named signals, sampled every `dt` seconds.
    pub fn new(dt: f64, names: &[String]) -> Self {
        Waveform {
            dt,
            traces: names.iter().map(|n| Trace { name: n.clone(), values: Vec::new() }).collect(),
        }
    }

    /// Append one sample per trace (must match trace count).
    pub fn push(&mut self, samples: &[f64]) {
        assert_eq!(samples.len(), self.traces.len(), "sample/trace count mismatch");
        for (t, &s) in self.traces.iter_mut().zip(samples) {
            t.values.push(s);
        }
    }

    /// Stored timesteps.
    pub fn len(&self) -> usize {
        self.traces.first().map_or(0, |t| t.values.len())
    }

    /// Whether no samples have been pushed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Time axis in seconds.
    pub fn times(&self) -> Vec<f64> {
        (0..self.len()).map(|i| i as f64 * self.dt).collect()
    }

    /// Render as CSV: `t,<name1>,<name2>,...`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t");
        for t in &self.traces {
            out.push(',');
            out.push_str(&t.name);
        }
        out.push('\n');
        for i in 0..self.len() {
            out.push_str(&format!("{:.4e}", i as f64 * self.dt));
            for t in &self.traces {
                out.push_str(&format!(",{:.6e}", t.values[i]));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_axes() {
        let mut w = Waveform::new(1e-9, &["a".into(), "b".into()]);
        assert!(w.is_empty());
        w.push(&[1.0, 2.0]);
        w.push(&[3.0, 4.0]);
        assert_eq!(w.len(), 2);
        assert_eq!(w.times(), vec![0.0, 1e-9]);
        assert_eq!(w.traces[1].values, vec![2.0, 4.0]);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut w = Waveform::new(0.5, &["x".into()]);
        w.push(&[1.5]);
        let csv = w.to_csv();
        assert!(csv.starts_with("t,x\n"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn push_wrong_arity_panics() {
        let mut w = Waveform::new(1.0, &["x".into()]);
        w.push(&[1.0, 2.0]);
    }
}
