//! Analog circuit layer (paper §3.3–§3.5): subthreshold MOS law, current
//! mirrors, the translinear `X²/Y` loop, and the Lazzaro O(N) winner-take-all
//! network with a transient ODE integrator.
//!
//! The paper validates these blocks in Cadence Spectre; we solve the same
//! subthreshold equations (Eq. 3–6 for the translinear loop, Eq. 8–14 for the
//! WTA small-signal dynamics) numerically. Each block exposes both a *static*
//! solve (operating point) and, for the WTA, a *transient* solve that yields
//! the settle latency the paper reports (search delay, Fig. 4b / Fig. 6).

mod mirror;
mod subthreshold;
mod translinear;
mod waveform;
mod wta;

pub use mirror::CurrentMirror;
pub use subthreshold::{ids_subthreshold, vgs_for_current};
pub use translinear::{Translinear, TranslinearInstance};
pub use waveform::{Trace, Waveform};
pub use wta::{Wta, WtaInstance, WtaOutcome};
