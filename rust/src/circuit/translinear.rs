//! Translinear squaring/division circuit (paper §3.3, Fig. 3b).
//!
//! The loop of CW transistors {M1, M4} and CCW transistors {M2, M5} in weak
//! inversion enforces ΣV_GS(CW) = ΣV_GS(CCW) (Eq. 4), which with the
//! exponential law (Eq. 3/5) yields `I_z = I_x² / I_y` (Eq. 6).
//!
//! The behavioral model adds what Spectre shows in Fig. 4a:
//! * a leakage floor at very small I_x (left flat region),
//! * soft compression once I_x pushes the CW devices out of weak inversion
//!   (right bend), with the knee set by `i_x_max`,
//! * per-instance gain error from MOS V_TH/size mismatch (Monte Carlo).

use crate::config::TranslinearConfig;
use crate::device::VariationSampler;
use crate::util::Rng;

/// Design-level (nominal) translinear block.
#[derive(Debug, Clone)]
pub struct Translinear {
    /// Design parameters.
    pub cfg: TranslinearConfig,
}

/// A fabricated instance with frozen mismatch, as used per array row.
#[derive(Debug, Clone)]
pub struct TranslinearInstance {
    /// Design parameters.
    pub cfg: TranslinearConfig,
    /// Frozen multiplicative gain error of the loop (V_TH mismatch around the
    /// translinear loop enters as a current-gain factor).
    pub gain: f64,
    /// Frozen additive input-referred offset on I_x (A).
    pub i_offset: f64,
}

impl Translinear {
    /// Nominal block with the given parameters.
    pub fn new(cfg: TranslinearConfig) -> Self {
        Translinear { cfg }
    }

    /// Ideal transfer (paper Eq. 6), used as the theory curve in Fig. 4a.
    pub fn transfer_ideal(&self, i_x: f64, i_y: f64) -> f64 {
        let i_y = i_y.max(1e-15);
        i_x.max(0.0).powi(2) / i_y
    }

    /// Behavioral transfer with leakage floor and weak-inversion compression.
    pub fn transfer(&self, i_x: f64, i_y: f64) -> f64 {
        let c = &self.cfg;
        let i_x = i_x.max(0.0);
        let i_y = i_y.max(1e-15);
        // Soft compression of the effective input beyond the weak-inversion
        // knee: x_eff → i_x_max as i_x → ∞ (CW devices leave subthreshold).
        let p = c.sat_sharpness;
        let x_eff = i_x / (1.0 + (i_x / c.i_x_max).powf(p)).powf(1.0 / p);
        x_eff * x_eff / i_y + c.i_leak
    }

    /// Fabricate an instance with frozen Monte Carlo mismatch.
    pub fn instance(&self, sampler: &VariationSampler, rng: &mut Rng) -> TranslinearInstance {
        // Four loop devices + two mirror legs contribute; their V_TH errors
        // combine into one loop gain (CW up, CCW down) — sample two stage
        // gains and take the ratio, matching the loop topology.
        let g_cw = sampler.stage_gain(rng);
        let g_ccw = sampler.stage_gain(rng);
        let gain = (g_cw / g_ccw).clamp(0.25, 4.0);
        // Input-referred offset from mirror leakage, small vs. operating range.
        let i_offset = self.cfg.i_x_min * 0.1 * (sampler.stage_gain(rng) - 1.0);
        TranslinearInstance { cfg: self.cfg.clone(), gain, i_offset }
    }

    /// Ideal (mismatch-free) instance.
    pub fn ideal_instance(&self) -> TranslinearInstance {
        TranslinearInstance { cfg: self.cfg.clone(), gain: 1.0, i_offset: 0.0 }
    }
}

impl TranslinearInstance {
    /// Output current of this fabricated row (A).
    pub fn output(&self, i_x: f64, i_y: f64) -> f64 {
        let t = Translinear { cfg: self.cfg.clone() };
        self.gain * t.transfer((i_x + self.i_offset).max(0.0), i_y)
    }

    /// Supply current drawn while settled (for the energy model): the loop
    /// conducts I_x (twice, CW pair), I_y, and I_z.
    pub fn supply_current(&self, i_x: f64, i_y: f64) -> f64 {
        2.0 * i_x.max(0.0) + i_y.max(0.0) + self.output(i_x, i_y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CosimeConfig, TranslinearConfig};
    use crate::util::rng;

    fn tl() -> Translinear {
        Translinear::new(TranslinearConfig::default())
    }

    #[test]
    fn ideal_is_x_squared_over_y() {
        let t = tl();
        assert!((t.transfer_ideal(600e-9, 600e-9) - 600e-9).abs() < 1e-15);
        assert!((t.transfer_ideal(300e-9, 600e-9) - 150e-9).abs() < 1e-15);
    }

    #[test]
    fn behavioral_matches_ideal_in_operating_region() {
        // Fig. 4a center region: simulated aligns with theory.
        let t = tl();
        let i_y = t.cfg.i_y_nominal;
        for &ix in &[20e-9, 100e-9, 300e-9, 600e-9] {
            let ideal = t.transfer_ideal(ix, i_y);
            let beh = t.transfer(ix, i_y);
            assert!(
                (beh - ideal).abs() / ideal < 0.05,
                "ix={ix}: ideal {ideal} vs behavioral {beh}"
            );
        }
    }

    #[test]
    fn compression_above_operating_range() {
        // Fig. 4a right bend: above i_x_max the output falls below ideal.
        let t = tl();
        let i_y = t.cfg.i_y_nominal;
        let ix = t.cfg.i_x_max * 8.0;
        let beh = t.transfer(ix, i_y);
        let ideal = t.transfer_ideal(ix, i_y);
        assert!(beh < 0.1 * ideal, "must compress: {beh} vs {ideal}");
    }

    #[test]
    fn leakage_floor_below_operating_range() {
        let t = tl();
        let out = t.transfer(0.0, t.cfg.i_y_nominal);
        assert!(out > 0.0 && out <= 2.0 * t.cfg.i_leak);
    }

    #[test]
    fn transfer_monotone_in_ix() {
        let t = tl();
        let i_y = t.cfg.i_y_nominal;
        let mut prev = -1.0;
        for step in 0..200 {
            let ix = 1e-9 * 1.06f64.powi(step);
            let z = t.transfer(ix, i_y);
            assert!(z >= prev, "non-monotone at ix={ix}");
            prev = z;
        }
    }

    #[test]
    fn larger_norm_divides_score_down() {
        let t = tl();
        let z1 = t.transfer(300e-9, 400e-9);
        let z2 = t.transfer(300e-9, 800e-9);
        assert!((z1 / z2 - 2.0).abs() < 0.05);
    }

    #[test]
    fn instance_gain_distribution_sane() {
        let cfg = CosimeConfig::default();
        let sampler = crate::device::VariationSampler::new(&cfg);
        let t = tl();
        let mut r = rng(11);
        let gains: Vec<f64> = (0..2000).map(|_| t.instance(&sampler, &mut r).gain).collect();
        let m = crate::util::mean(&gains);
        let sd = crate::util::stddev(&gains);
        assert!((m - 1.0).abs() < 0.25, "mean {m}");
        // Loop gain sigma ~ sqrt(2) × stage sigma; must be nonzero but bounded.
        assert!(sd > 0.1 && sd < 1.0, "sd {sd}");
    }

    #[test]
    fn ideal_instance_reproduces_nominal() {
        let t = tl();
        let inst = t.ideal_instance();
        let i_y = t.cfg.i_y_nominal;
        assert!((inst.output(300e-9, i_y) - t.transfer(300e-9, i_y)).abs() < 1e-18);
    }
}
