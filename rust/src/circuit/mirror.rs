//! Current mirror with gain and mismatch. COSIME uses mirrors to (a) copy the
//! array wordline currents into the translinear loop, (b) amplify the
//! translinear outputs up to the WTA working range ("amplification current
//! mirrors", §4.1), and (c) close the WTA excitatory feedback path (Fig. 3c).

/// A (possibly ratioed) current mirror: `I_out = gain × mismatch × I_in`.
#[derive(Debug, Clone, Copy)]
pub struct CurrentMirror {
    /// Design gain from the W/L ratio of the output leg.
    pub gain: f64,
    /// Frozen multiplicative mismatch of this instance (1.0 = ideal).
    pub mismatch: f64,
    /// Compliance limit: the output leg saturates at this current (A).
    pub i_max: f64,
}

impl CurrentMirror {
    /// Perfect mirror with the given gain (no mismatch, no compliance cap).
    pub fn ideal(gain: f64) -> Self {
        CurrentMirror { gain, mismatch: 1.0, i_max: f64::INFINITY }
    }

    /// Mirror with a frozen multiplicative mismatch factor.
    pub fn with_mismatch(gain: f64, mismatch: f64) -> Self {
        CurrentMirror { gain, mismatch, i_max: f64::INFINITY }
    }

    /// Mirror an input current through this instance.
    pub fn copy(&self, i_in: f64) -> f64 {
        (self.gain * self.mismatch * i_in.max(0.0)).min(self.i_max)
    }

    /// Supply charge drawn per unit time by both legs while conducting
    /// (used by the energy model: input + output legs both burn I×V).
    pub fn supply_current(&self, i_in: f64) -> f64 {
        i_in.max(0.0) + self.copy(i_in)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_mirror_copies() {
        let m = CurrentMirror::ideal(1.0);
        assert_eq!(m.copy(3e-7), 3e-7);
        let m = CurrentMirror::ideal(20.0);
        assert_eq!(m.copy(1e-7), 2e-6);
    }

    #[test]
    fn mismatch_scales_output() {
        let m = CurrentMirror::with_mismatch(2.0, 1.1);
        assert!((m.copy(1e-6) - 2.2e-6).abs() < 1e-18);
    }

    #[test]
    fn negative_input_clamped() {
        let m = CurrentMirror::ideal(1.0);
        assert_eq!(m.copy(-1e-6), 0.0);
    }

    #[test]
    fn compliance_limit_saturates() {
        let mut m = CurrentMirror::ideal(10.0);
        m.i_max = 5e-6;
        assert_eq!(m.copy(1e-6), 5e-6);
    }

    #[test]
    fn supply_current_counts_both_legs() {
        let m = CurrentMirror::ideal(3.0);
        assert!((m.supply_current(1e-6) - 4e-6).abs() < 1e-18);
    }
}
