//! COSIME CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   fig1 | fig2 | fig4a | fig4b | fig6 | fig7 | fig8 | fig9 | table1 | table2
//!       regenerate the corresponding paper table/figure (see rust/README.md)
//!   all       run every regeneration (writes results/ + prints everything)
//!   search    one-off NN search over random or worst-case stored words
//!   serve     start the AM serving engine and drive a synthetic workload
//!             (--snapshot PATH warm-starts from a saved AM snapshot);
//!             with --listen ADDR it instead serves the cosimed wire
//!             protocol over TCP (--shards S fans the store across S
//!             coordinator stacks; --io threaded|eventloop picks the I/O
//!             engine; --duration SECS exits after a while, 0 = run until
//!             killed; see examples/loadgen.rs for a client;
//!             --replica-of HOST:PORT joins a primary instead of loading a
//!             local store: snapshot pull + catch-up replay, then serve
//!             while a background thread keeps tracking)
//!   route     start a routing tier: a cosimed server whose shards are
//!             *remote* cosimed servers (--remote a:p,b:p or
//!             `[server] remote_shards` in --config), scatter-gather over
//!             the wire with the same global-id scheme as local shards
//!   replicate pull one epoch-consistent snapshot from a live primary over
//!             the wire (--from HOST:PORT) and persist it as a local AM
//!             snapshot (--out PATH), catch-up log replayed to the serving
//!             epoch first
//!   hdc       train + evaluate the HDC case study end to end
//!             (--snapshot PATH saves the trained AM, write costs included)
//!   live      train → snapshot → warm-start a server → stream online HDC
//!             class updates through the coordinator's admin plane
//!   artifacts list the AOT artifacts the runtime can load
//!   bench     regenerate the machine-readable perf rail: runs the kernel
//!             and serving benches and writes BENCH_kernel.json /
//!             BENCH_serving.json (--out DIR, default repo root `.`;
//!             --quick trims the grid for CI smoke; --only kernel|serving
//!             runs one rail; --check only validates existing artifacts;
//!             --append records a dated headline entry into
//!             BENCH_trajectory.json for longitudinal tracking)
//!   lint      run the in-crate invariant linter (SAFETY comments, no-panic
//!             serving paths, hot-path allocation regions, lock ordering,
//!             epoch-write discipline, wire/config exhaustiveness; --json
//!             for machine-readable findings, non-zero exit when anything
//!             fires; --waivers lists every `lint: allow` escape hatch
//!             with its reason and introducing commit instead)
//!
//! Common flags: --results DIR, --seed N, --subsample F (dataset fraction),
//! --trials N (Monte Carlo), --engine digital|analog|xla|multibit.
//!
//! Kernel dispatch: the popcount kernel path (scalar/avx2/avx512/neon) is
//! resolved once at startup from `COSIME_KERNEL`, falling back to the
//! `[kernel] path` config key, then to the widest path the CPU supports.

use anyhow::{bail, Result};
use cosime::am::kernel::simd;
use cosime::am::store::AmStore;
use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::{CosimeConfig, IoMode};
use cosime::coordinator::{AdminOp, AmService, Backend, LocalBackend, TileManager};
use cosime::hdc::{
    evaluate_service_accuracy, Dataset, DatasetSpec, HdcModel, SyntheticParams, TrainConfig,
};
use cosime::repro;
use cosime::runtime::{RuntimeHandle, XlaAmEngine};
use cosime::server::{
    bootstrap, CosimeServer, RemoteBackend, ReplicaSync, RouterBackend, ShardRouter,
};
use cosime::util::cli::Args;
use cosime::util::{rng, BitVec};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    let results = args.get("results");
    let sub = args.get_f64("subsample", 0.05);
    let trials = args.get_usize("trials", 100);
    match args.subcommand.as_deref() {
        Some("fig1") => repro::fig1::run(sub, results),
        Some("fig2") => repro::fig2::run(results),
        Some("fig4a") => repro::fig4::run_a(results),
        Some("fig4b") => repro::fig4::run_b(results),
        Some("fig4") => {
            repro::fig4::run_a(results)?;
            repro::fig4::run_b(results)
        }
        Some("fig6") => repro::fig6::run(args.get_str("sweep", "both"), results),
        Some("fig7") => match args.get_str("part", "both") {
            "a" => repro::fig7::run_a(trials, results),
            "b" => repro::fig7::run_b(trials, results),
            _ => {
                repro::fig7::run_a(trials, results)?;
                repro::fig7::run_b(trials, results)
            }
        },
        Some("fig8") => repro::fig8::run(results),
        Some("fig9") => match args.get_str("part", "all") {
            "a" => repro::fig9::run_a(sub, results),
            "b" | "c" | "bc" => repro::fig9::run_bc(results),
            _ => {
                repro::fig9::run_a(sub, results)?;
                repro::fig9::run_bc(results)
            }
        },
        Some("table1") => repro::table1::run(),
        Some("table2") => repro::table2::run(),
        Some("all") => run_all(sub, trials, results),
        Some("search") => cmd_search(args),
        Some("serve") => cmd_serve(args),
        Some("route") => cmd_route(args),
        Some("replicate") => cmd_replicate(args),
        Some("hdc") => cmd_hdc(args),
        Some("live") => cmd_live(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("bench") => cmd_bench(args),
        Some("lint") => cmd_lint(args),
        Some(other) => bail!("unknown subcommand '{other}' (see README)"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "cosime — FeFET in-memory cosine-similarity search engine (ICCAD'22 reproduction)\n\n\
         usage: cosime <subcommand> [flags]\n\n\
         repro:  fig1 fig2 fig4a fig4b fig6 fig7 fig8 fig9 table1 table2 all\n\
         system: search serve route replicate hdc live artifacts bench lint\n\n\
         flags:  --results DIR  --seed N  --subsample F  --trials N\n\
                 --engine digital|analog|xla|multibit  --rows N --dims N --queries N --k N\n\
                 --snapshot PATH (hdc: save trained AM; serve: warm-start from it)\n\
                 --listen ADDR --shards S --io threaded|eventloop --duration SECS\n\
                 --config FILE (serve: TCP frontend; drive it with\n\
                 `cargo run --release --example loadgen`)\n\
                 --remote A:P,B:P (route: the remote shard servers to fan over)\n\
                 --replica-of HOST:PORT (serve: join a primary over the wire)\n\
                 --from HOST:PORT --out PATH (replicate: snapshot a primary)\n\
                 --out DIR --quick --only kernel|serving --check (bench)\n\
         env:    COSIME_KERNEL=auto|scalar|avx2|avx512|neon forces the popcount\n\
                 kernel dispatch path (unavailable paths fall back with a warning)"
    );
}

fn run_all(sub: f64, trials: usize, results: Option<&str>) -> Result<()> {
    repro::table2::run()?;
    println!();
    repro::table1::run()?;
    println!();
    repro::fig1::run(sub, results)?;
    println!();
    repro::fig2::run(results)?;
    println!();
    repro::fig4::run_a(results)?;
    println!();
    repro::fig4::run_b(results)?;
    println!();
    repro::fig6::run("both", results)?;
    println!();
    repro::fig7::run_a(trials, results)?;
    println!();
    repro::fig7::run_b(trials, results)?;
    println!();
    repro::fig8::run(results)?;
    println!();
    repro::fig9::run_a(sub, results)?;
    println!();
    repro::fig9::run_bc(results)
}

/// Build an engine per --engine over the given words. `multibit` packs the
/// words into 2-bit cell planes by default; the `[engine] bits` config key
/// selects 4-bit cells.
fn build_engine(kind: &str, words: Vec<BitVec>, seed: u64) -> Result<Box<dyn AmEngine>> {
    let cfg = CosimeConfig::default();
    match kind {
        "digital" => Ok(Box::new(DigitalExactEngine::new(words))),
        "multibit" => Ok(Box::new(cosime::am::MultiBitEngine::new(words, cfg.engine.bits))),
        "analog" => {
            let mut r = rng(seed);
            Ok(Box::new(cosime::am::analog::AnalogCosimeEngine::new(&cfg, words, &mut r)))
        }
        "xla" => {
            let rt = RuntimeHandle::spawn("artifacts")?;
            let dims = words[0].len();
            let rows = words.len();
            // Pick the smallest matching artifact geometry.
            let artifact = if rows <= 32 && dims == 128 {
                "cosime_search_r32_d128_b4"
            } else if rows <= 256 && dims == 1024 {
                "cosime_search_r256_d1024_b8"
            } else if rows <= 256 && dims == 256 {
                "cosime_search_r256_d256_b8"
            } else {
                bail!("no artifact for rows={rows}, dims={dims}; run `make artifacts`")
            };
            Ok(Box::new(XlaAmEngine::new(&rt, artifact, &words)?))
        }
        other => bail!("unknown engine '{other}'"),
    }
}

fn cmd_search(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 256);
    let dims = args.get_usize("dims", 1024);
    let seed = args.get_u64("seed", 1);
    let k = args.get_usize("k", 1);
    let engine_kind = args.get_str("engine", "digital");
    let mut r = rng(seed);
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let query = words[rows / 2].clone();
    let engine = build_engine(engine_kind, words, seed)?;
    let t0 = Instant::now();
    let ranked = engine.search_topk(&query, k.max(1));
    let dt = t0.elapsed();
    println!(
        "engine={} rows={rows} dims={dims} k={} ({:.1} µs wall)",
        engine.name(),
        ranked.len(),
        dt.as_secs_f64() * 1e6
    );
    for (rank, res) in ranked.iter().enumerate() {
        println!("  #{:<3} winner={} score={:.4}", rank + 1, res.winner, res.score);
    }
    assert_eq!(ranked[0].winner, rows / 2, "self-query must match itself");
    println!("self-query sanity: OK");
    Ok(())
}

/// Load the store for `serve`: snapshot warm-start when given, random
/// words otherwise.
fn serve_words(args: &Args, cfg: &CosimeConfig, seed: u64) -> Result<Vec<BitVec>> {
    if let Some(snap) = args.get("snapshot") {
        let store = AmStore::load(cfg, snap)?;
        anyhow::ensure!(!store.is_empty(), "snapshot {snap} has no rows to serve");
        println!(
            "warm start: {} rows x {} bits from {snap} (programmed cost: {})",
            store.rows(),
            store.dims(),
            store.write_stats().report()
        );
        Ok(store.words().to_vec())
    } else {
        let rows = args.get_usize("rows", 1024);
        let dims = args.get_usize("dims", 1024);
        anyhow::ensure!(rows >= 1, "need at least one row to serve (--rows)");
        anyhow::ensure!(dims >= 1, "need at least one bit per word (--dims)");
        let mut r = rng(seed);
        Ok((0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect())
    }
}

/// `serve --listen ADDR`: the networked frontend. Binds the cosimed wire
/// protocol, fans the store across `--shards` coordinator stacks, and
/// serves until `--duration` seconds elapse (0 = until killed).
fn cmd_serve_tcp(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => CosimeConfig::from_toml_file(path)?,
        None => CosimeConfig::default(),
    };
    if let Some(listen) = args.get("listen") {
        cfg.server.listen = listen.to_string();
    }
    cfg.server.shards = args.get_usize("shards", cfg.server.shards);
    if let Some(io) = args.get("io") {
        cfg.server.io = IoMode::parse(io)?;
    }
    cfg.validate()?;
    let kern = simd::pin(&cfg.kernel.path);
    println!("search kernel: {} dispatch", kern.path().as_str());
    if let Some(primary) = args.get("replica-of") {
        return serve_replica(args, &cfg, primary);
    }
    let seed = args.get_u64("seed", 2);
    let engine_kind = args.get_str("engine", "digital").to_string();
    let words = serve_words(args, &cfg, seed)?;
    let (rows, dims) = (words.len(), words[0].len());
    let ek = engine_kind.clone();
    let router = ShardRouter::build(&cfg, cfg.server.shards, cfg.array.rows, words, move |w| {
        build_engine(&ek, w, seed)
    })?;
    println!(
        "sharded {rows} words x {dims} bits across {} shard(s) ({} engine, {} workers each)",
        router.shard_count(),
        engine_kind,
        cfg.coordinator.workers
    );
    let server = CosimeServer::serve(&cfg.server, router)?;
    println!(
        "cosimed listening on {} ({} io, max_frame {} B, {} in-flight frames/conn)",
        server.local_addr(),
        server.io_mode().as_str(),
        cfg.server.max_frame,
        cfg.server.max_inflight
    );
    serve_until_done(args, server)
}

/// Shared tail of `serve`/`route`: hold the server open for `--duration`
/// seconds (0 = until killed), then report and shut down.
fn serve_until_done(args: &Args, server: CosimeServer) -> Result<()> {
    let duration = args.get_u64("duration", 0);
    if duration > 0 {
        std::thread::sleep(std::time::Duration::from_secs(duration));
        match server.backend().metrics() {
            Ok(m) => println!("\n{}", m.report()),
            Err(e) => println!("\n(metrics unavailable at shutdown: {e})"),
        }
        server.shutdown();
        Ok(())
    } else {
        println!("(serving until killed; pass --duration SECS to exit on a timer)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
}

/// `route --listen ADDR --remote A:P,B:P`: a routing tier. Each remote
/// address becomes one nonblocking wire connection ([`RemoteBackend`]);
/// the router scatter-gathers over them with the same `shard << 48 | local`
/// global-id scheme as in-process shards, so clients cannot tell a routing
/// tier from a flat server.
fn cmd_route(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => CosimeConfig::from_toml_file(path)?,
        None => CosimeConfig::default(),
    };
    if let Some(listen) = args.get("listen") {
        cfg.server.listen = listen.to_string();
    }
    if let Some(io) = args.get("io") {
        cfg.server.io = IoMode::parse(io)?;
    }
    if let Some(remote) = args.get("remote") {
        cfg.server.remote_shards =
            remote.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    }
    cfg.validate()?;
    anyhow::ensure!(
        !cfg.server.remote_shards.is_empty(),
        "route needs shard addresses: --remote A:P,B:P or [server] remote_shards in --config"
    );
    let mut children: Vec<Box<dyn Backend>> = Vec::with_capacity(cfg.server.remote_shards.len());
    for addr in &cfg.server.remote_shards {
        let child = RemoteBackend::connect_retry(
            addr.as_str(),
            10,
            std::time::Duration::from_millis(200),
        )?;
        let h = child.connect_health();
        println!("shard {addr}: {} rows x {} bits, epoch {}", h.rows, h.dims, h.epoch);
        children.push(Box::new(child));
    }
    let router = RouterBackend::from_backends(children)?;
    let shards = router.shard_count();
    let server = CosimeServer::serve(&cfg.server, router)?;
    println!(
        "routing tier on {} ({} io) over {} remote shard(s)",
        server.local_addr(),
        server.io_mode().as_str(),
        shards
    );
    serve_until_done(args, server)
}

/// `serve --listen ADDR --replica-of PRIMARY`: join a primary over the wire
/// — pull an epoch-consistent snapshot cut, replay the catch-up log to the
/// primary's serving epoch — then serve the replica store while a
/// background sync thread keeps tracking new commits. The sync cadence and
/// snapshot chunk size come from `[replication]`; the hello secret (if the
/// primary requires one) from `[server] auth_secret`.
fn serve_replica(args: &Args, cfg: &CosimeConfig, primary: &str) -> Result<()> {
    let seed = args.get_u64("seed", 2);
    let engine_kind = args.get_str("engine", "digital").to_string();
    let backoff = std::time::Duration::from_millis(cfg.replication.probe_backoff_ms);
    let source = RemoteBackend::connect_opts(primary, cfg.server.auth_secret.as_bytes(), backoff)?;
    let h = source.connect_health();
    println!("primary {primary}: {} rows x {} bits, epoch {}", h.rows, h.dims, h.epoch);
    let ek = engine_kind.clone();
    let factory = move |w: Vec<BitVec>| build_engine(&ek, w, seed);
    let svc = bootstrap(
        &source,
        cfg,
        cfg.array.rows,
        cfg.replication.snapshot_chunk_rows as u64,
        factory,
    )
    .map_err(|e| anyhow::anyhow!("replica bootstrap from {primary}: {e}"))?;
    println!(
        "replica store: {} rows x {} bits at epoch {} ({} engine)",
        svc.rows(),
        svc.dims(),
        svc.epoch(),
        engine_kind
    );
    let sync = ReplicaSync::spawn(Box::new(source), svc.clone(), backoff);
    let server =
        CosimeServer::serve_backend(&cfg.server, std::sync::Arc::new(LocalBackend::new(svc)))?;
    println!(
        "cosimed replica listening on {} ({} io), tracking {primary} every {} ms",
        server.local_addr(),
        server.io_mode().as_str(),
        cfg.replication.probe_backoff_ms
    );
    let done = serve_until_done(args, server);
    if sync.stale() {
        eprintln!("warning: replica fell below the primary's catch-up log; re-run to re-snapshot");
    }
    sync.stop();
    done
}

/// `replicate --from PRIMARY --out PATH`: pull one epoch-consistent
/// snapshot cut from a live primary over the wire, replay the catch-up log
/// to the serving epoch, and persist the result as a local AM snapshot.
/// Every row goes through the write-verify programming path on the way to
/// disk, so the saved store carries real write costs like any other
/// snapshot and warm-starts `serve --snapshot` directly.
fn cmd_replicate(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => CosimeConfig::from_toml_file(path)?,
        None => CosimeConfig::default(),
    };
    cfg.validate()?;
    let primary = match args.get("from") {
        Some(a) => a,
        None => bail!("replicate needs a primary: --from HOST:PORT"),
    };
    let out = match args.get("out") {
        Some(p) => p,
        None => bail!("replicate needs a destination: --out PATH"),
    };
    let backoff = std::time::Duration::from_millis(cfg.replication.probe_backoff_ms);
    let source = RemoteBackend::connect_opts(primary, cfg.server.auth_secret.as_bytes(), backoff)?;
    let h = source.connect_health();
    println!("primary {primary}: {} rows x {} bits, epoch {}", h.rows, h.dims, h.epoch);
    // A short-lived local service lets the catch-up replay run through the
    // same epoch-CAS path a serving replica uses, so the persisted cut is
    // the primary's *serving* epoch, not just the snapshot pin.
    let svc = bootstrap(
        &source,
        &cfg,
        cfg.array.rows,
        cfg.replication.snapshot_chunk_rows as u64,
        |w| Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>),
    )
    .map_err(|e| anyhow::anyhow!("replica pull from {primary}: {e}"))?;
    let words = svc.snapshot_words();
    let epoch = svc.epoch();
    svc.shutdown();
    source.close();
    let mut store = AmStore::new(&cfg, words[0].len());
    for (i, w) in words.iter().enumerate() {
        store.insert(&format!("row-{i}"), w)?;
    }
    store.save(out)?;
    println!(
        "replicated {} rows x {} bits (cut epoch {epoch}) -> {out} ({})",
        store.rows(),
        store.dims(),
        store.write_stats().report()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return cmd_serve_tcp(args);
    }
    let queries = args.get_usize("queries", 2000);
    let seed = args.get_u64("seed", 2);
    let engine_kind = args.get_str("engine", "digital").to_string();
    let cfg = CosimeConfig::default();
    let kern = simd::pin(&cfg.kernel.path);
    println!("search kernel: {} dispatch", kern.path().as_str());
    let words = serve_words(args, &cfg, seed)?;
    let (rows, dims) = (words.len(), words[0].len());
    let tile_rows = cfg.array.rows;
    let ek = engine_kind.clone();
    let tiles = TileManager::build(words, tile_rows, move |w| build_engine(&ek, w, seed))?;
    println!(
        "serving {rows} words x {dims} bits on {} tiles ({} engine), workers={}",
        tiles.tile_count(),
        engine_kind,
        cfg.coordinator.workers
    );
    let svc = AmService::start_with_config(&cfg, tiles);

    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let svc = svc.clone();
            s.spawn(move || {
                let mut r = rng(seed ^ (c + 10));
                for _ in 0..queries / 4 {
                    let q = BitVec::random(dims, 0.5, &mut r);
                    let _ = svc.search_with_retry(q, 20);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let m = svc.metrics();
    println!("\n{}", m.report());
    println!(
        "\nthroughput: {:.0} queries/s over {:.1} ms wall",
        m.completed as f64 / wall.as_secs_f64(),
        wall.as_secs_f64() * 1e3
    );
    svc.shutdown();
    Ok(())
}

fn cmd_hdc(args: &Args) -> Result<()> {
    let sub = args.get_f64("subsample", 0.05);
    let dims = args.get_usize("dims-hv", 1024);
    let dataset = match args.get_str("dataset", "isolet") {
        "ucihar" => DatasetSpec::Ucihar,
        "face" => DatasetSpec::Face,
        "isolet" => DatasetSpec::Isolet,
        other => bail!("unknown dataset '{other}'"),
    };
    let ds =
        Dataset::synthetic(dataset, SyntheticParams { subsample: sub, ..Default::default() }, 1);
    println!(
        "HDC on {} (synthetic, Table 2 shape): {} train / {} test, K={}, D={dims}",
        ds.name,
        ds.train_len(),
        ds.test_len(),
        ds.classes
    );
    let t0 = Instant::now();
    let model = HdcModel::train(&ds, TrainConfig { dims, epochs: 2, seed: 3, ..Default::default() });
    println!("trained in {:.2} s", t0.elapsed().as_secs_f64());
    let engine = build_engine(args.get_str("engine", "digital"), model.class_hypervectors(), 4)?;
    // Batched inference through the block kernel (the serving shape).
    let encoded: Vec<BitVec> = ds.test_x.iter().map(|x| model.encoder.encode(x)).collect();
    let t1 = Instant::now();
    let results = engine.search_batch(&encoded);
    let dt = t1.elapsed();
    let correct = results.iter().zip(&ds.test_y).filter(|(res, &y)| res.winner == y).count();
    println!(
        "accuracy: {:.1} % ({}/{}) | inference {:.1} µs/query ({} engine)",
        100.0 * correct as f64 / ds.test_len() as f64,
        correct,
        ds.test_len(),
        dt.as_secs_f64() * 1e6 / ds.test_len() as f64,
        engine.name()
    );

    // Persist the trained AM (programming every class hypervector through
    // the write-verify path, so the snapshot carries the real write cost).
    if let Some(path) = args.get("snapshot") {
        let cfg = CosimeConfig::default();
        let mut store = AmStore::new(&cfg, dims);
        for (c, hv) in model.class_hypervectors().iter().enumerate() {
            store.insert(&format!("class-{c}"), hv)?;
        }
        store.save(path)?;
        println!("snapshot: {} rows -> {path} ({})", store.rows(), store.write_stats().report());
    }
    Ok(())
}

/// End-to-end live-update demo: train HDC, snapshot the AM to disk,
/// warm-start a server from the snapshot, then stream online retraining
/// updates through the coordinator's admin plane and re-evaluate — the
/// write→serve loop closed, with write energy/latency from the verify loop.
fn cmd_live(args: &Args) -> Result<()> {
    let sub = args.get_f64("subsample", 0.05);
    let dims = args.get_usize("dims-hv", 512);
    let updates = args.get_usize("updates", 200);
    let cfg = CosimeConfig::default();
    let ds = Dataset::synthetic(
        DatasetSpec::Isolet,
        SyntheticParams { subsample: sub, ..Default::default() },
        1,
    );
    // epochs = 0 leaves mistakes for the online phase to fix.
    let mut model =
        HdcModel::train(&ds, TrainConfig { dims, epochs: 0, seed: 3, ..Default::default() });

    // Snapshot the trained AM.
    let dir = std::env::temp_dir().join(format!("cosime-live-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let snap = dir.join("am.json");
    let mut store = AmStore::new(&cfg, dims);
    for (c, hv) in model.class_hypervectors().iter().enumerate() {
        store.insert(&format!("class-{c}"), hv)?;
    }
    store.save(&snap)?;
    println!("snapshot: {} classes -> {:?} ({})", store.rows(), snap, store.write_stats().report());

    // Warm-start the serving stack from disk.
    let store = AmStore::load(&cfg, &snap)?;
    let tiles = TileManager::build(store.words().to_vec(), cfg.array.rows, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })?;
    let svc = AmService::start_with_config(&cfg, tiles);
    let before = evaluate_service_accuracy(&ds, &model, &svc);
    println!(
        "warm-started server: {} rows, accuracy {:.1} % (epoch {})",
        svc.rows(),
        100.0 * before.accuracy(),
        svc.epoch()
    );

    // Online retraining: each mistaken train sample reprograms the touched
    // class rows through the admin plane.
    let n = updates.min(ds.train_len());
    let mut reprogrammed = 0usize;
    for i in 0..n {
        for c in model.online_update(&ds.train_x[i], ds.train_y[i]) {
            svc.admin(AdminOp::Update { row: c, word: model.class_hypervector(c) })?;
            reprogrammed += 1;
        }
    }
    let after = evaluate_service_accuracy(&ds, &model, &svc);
    let m = svc.metrics();
    println!(
        "online phase: {n} samples, {reprogrammed} class reprograms -> epoch {}\n\
         write cost: {} pulses, {:.2} nJ, {:.1} µs array time\n\
         accuracy: {:.1} % -> {:.1} %",
        svc.epoch(),
        m.write.pulses,
        m.write.energy_j * 1e9,
        m.write.latency_s * 1e6,
        100.0 * before.accuracy(),
        100.0 * after.accuracy(),
    );
    println!("\n{}", m.report());
    svc.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// `bench`: regenerate (or `--check`) the machine-readable perf rail.
/// One invocation rewrites both `BENCH_kernel.json` and `BENCH_serving.json`
/// under `--out` (default `.`, i.e. the repo root when run from there).
fn cmd_bench(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => CosimeConfig::from_toml_file(path)?,
        None => CosimeConfig::default(),
    };
    cfg.validate()?;
    let kern = simd::pin(&cfg.kernel.path);
    let avail: Vec<&str> =
        simd::KernelImpl::available().iter().map(|p| p.as_str()).collect();
    println!(
        "kernel dispatch: active={} available=[{}] (override with {}=PATH)",
        kern.path().as_str(),
        avail.join(", "),
        simd::ENV_VAR
    );
    let out_dir = std::path::PathBuf::from(args.get_str("out", "."));
    if args.flag("check") {
        cosime::perf::check_artifacts(&out_dir)?;
        println!(
            "BENCH_kernel.json and BENCH_serving.json in {} are schema-valid",
            out_dir.display()
        );
        return Ok(());
    }
    let quick = args.flag("quick");
    let written = cosime::perf::write_artifacts(&out_dir, quick, args.get("only"))?;
    for p in &written {
        println!("wrote {}", p.display());
    }
    if args.flag("append") {
        let tp = cosime::perf::append_trajectory(&out_dir)?;
        println!("appended trajectory entry to {}", tp.display());
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => cosime::lint::repo_root()
            .ok_or_else(|| anyhow::anyhow!("could not locate the repo root (rust/src/lib.rs)"))?,
    };
    if args.flag("waivers") {
        // Audit mode: list every `lint: allow` escape hatch instead of
        // linting. Always exits 0 — waivers are documented, not wrong.
        let waivers = cosime::lint::waiver_report(&root)?;
        if args.flag("json") {
            println!("{}", cosime::lint::render_waivers_json(&waivers));
        } else {
            print!("{}", cosime::lint::render_waivers_text(&waivers));
        }
        return Ok(());
    }
    let findings = cosime::lint::lint_tree(&root)?;
    if args.flag("json") {
        println!("{}", cosime::lint::render_json(&findings));
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!(
            "cosime lint: {} finding{} across the tree",
            findings.len(),
            if findings.len() == 1 { "" } else { "s" }
        );
    }
    if findings.is_empty() {
        Ok(())
    } else {
        // Non-zero exit without the `error:` banner noise on top of the
        // already-printed findings.
        std::process::exit(2);
    }
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get_str("dir", "artifacts");
    let rt = RuntimeHandle::spawn(dir)?;
    println!("platform: {}", rt.platform()?);
    for name in rt.names()? {
        let sig = rt.signature(&name)?;
        let ins: Vec<String> =
            sig.inputs.iter().map(|t| format!("{:?}:{}", t.shape, t.dtype)).collect();
        println!("  {name}  inputs=[{}]", ins.join(", "));
    }
    Ok(())
}
