//! Behavioral FeFET model: two-state nonvolatile V_TH with a Preisach-style
//! switching characteristic for writes, and a simple subthreshold/ohmic I–V
//! for reads (enough to reproduce Fig. 2a/b and the write path).

use crate::config::{consts, DeviceConfig};

/// Remanent polarization state of the ferroelectric layer, normalized to
/// [-1, +1]. +1 ⇒ fully set (low V_TH, stores '1'); -1 ⇒ fully reset
/// (high V_TH, stores '0').
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolarizationState(pub f64);

impl PolarizationState {
    /// Fully set polarization (stores '1').
    pub fn set() -> Self {
        PolarizationState(1.0)
    }
    /// Fully reset polarization (stores '0').
    pub fn reset() -> Self {
        PolarizationState(-1.0)
    }
    /// Binary readout: a device is considered to store '1' when more than
    /// half of its domains are polarized "set".
    pub fn stores_one(&self) -> bool {
        self.0 > 0.0
    }
}

/// A single FeFET with an instance-specific V_TH offset (device-to-device
/// variation is frozen at fabrication, not redrawn per read — paper Fig. 7
/// samples *dies*, not reads).
#[derive(Debug, Clone)]
pub struct FeFet {
    state: PolarizationState,
    /// Frozen V_TH offsets for the two states (V), sampled at build time.
    pub dvth_low: f64,
    /// Frozen V_TH offset of the high-V_TH (reset) state (V).
    pub dvth_high: f64,
}

impl Default for FeFet {
    fn default() -> Self {
        FeFet { state: PolarizationState::reset(), dvth_low: 0.0, dvth_high: 0.0 }
    }
}

impl FeFet {
    /// Fresh device with explicit variation offsets.
    pub fn with_offsets(dvth_low: f64, dvth_high: f64) -> Self {
        FeFet { state: PolarizationState::reset(), dvth_low, dvth_high }
    }

    /// Current polarization.
    pub fn state(&self) -> PolarizationState {
        self.state
    }

    /// Effective threshold voltage under the current polarization (V).
    /// Partial polarization interpolates between the two states, which is how
    /// the Preisach model's minor loops manifest at the terminal level.
    pub fn vth(&self, cfg: &DeviceConfig) -> f64 {
        let lo = cfg.vth_low + self.dvth_low;
        let hi = cfg.vth_high + self.dvth_high;
        let w = (self.state.0 + 1.0) / 2.0; // 0 → high-V_TH, 1 → low-V_TH
        hi + (lo - hi) * w
    }

    /// Apply a gate write pulse of amplitude `v_g` (V) and width `t` (s).
    ///
    /// Preisach-lite: the saturated target polarization is a tanh of the
    /// overdrive beyond the coercive voltage, and the state relaxes toward it
    /// with a nucleation-limited time constant that shrinks exponentially
    /// with overdrive (reproducing the strong pulse-amplitude dependence of
    /// HfO₂ FeFET switching [26]).
    pub fn write_pulse(&mut self, v_g: f64, t: f64, _cfg: &DeviceConfig) {
        const V_COERCIVE: f64 = 2.2; // typical HfO₂ FeFET coercive gate voltage
        const TAU0: f64 = 10e-6; // switching time at the coercive voltage
        const V_ACT: f64 = 0.45; // activation slope (V/decade-ish)
        const V_SAT: f64 = 0.35; // overdrive for full polarization saturation
        let overdrive = (v_g.abs() - V_COERCIVE).max(0.0);
        // Sub-coercive pulses only disturb toward depolarization (target 0);
        // beyond the coercive voltage the target polarization saturates fast.
        let target = v_g.signum() * (overdrive / V_SAT).tanh();
        let tau = TAU0 * (-overdrive / V_ACT).exp();
        let alpha = 1.0 - (-t / tau).exp();
        self.state = PolarizationState(self.state.0 + (target - self.state.0) * alpha);
    }

    /// Program the device to store `bit` using the paper's ±4 V pulses.
    pub fn program(&mut self, bit: bool, cfg: &DeviceConfig) {
        let v = if bit { cfg.v_write } else { -cfg.v_write };
        self.write_pulse(v, cfg.t_write, cfg);
    }

    /// Drain current at gate voltage `v_g`, drain bias `v_d` with no series
    /// resistor (Fig. 2b): subthreshold exponential that soft-saturates at
    /// the ohmic/saturation current once V_G clears V_TH.
    pub fn id(&self, v_g: f64, v_d: f64, cfg: &DeviceConfig) -> f64 {
        let vth = self.vth(cfg);
        let n_vt = cfg.eta * consts::V_T;
        // Subthreshold branch, clamped for numerical safety.
        let sub = cfg.i0 * ((v_g - vth) / n_vt).min(40.0).exp();
        // Above-threshold branch: crude square-law capped by i0 scale.
        let sat = if v_g > vth { cfg.i0 * (1.0 + 8.0 * (v_g - vth)) } else { cfg.i0 };
        let i = sub.min(sat);
        // Linear drain dependence at small v_d, saturating (ohmic knee).
        i * (v_d / (v_d + 0.05)).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    #[test]
    fn program_switches_state() {
        let cfg = DeviceConfig::default();
        let mut f = FeFet::default();
        assert!(!f.state().stores_one());
        f.program(true, &cfg);
        assert!(f.state().stores_one(), "positive pulse must set low-V_TH");
        assert!(f.state().0 > 0.95, "±4 V, 1 µs must fully switch");
        f.program(false, &cfg);
        assert!(!f.state().stores_one());
        assert!(f.state().0 < -0.95);
    }

    #[test]
    fn weak_pulse_only_partially_switches() {
        let cfg = DeviceConfig::default();
        let mut f = FeFet::default();
        // At the coercive voltage the switching time blows up: 1 ns pulse
        // barely moves the polarization.
        f.write_pulse(2.2, 1e-9, &cfg);
        assert!(f.state().0 < -0.9, "sub-coercive short pulse must not switch");
    }

    #[test]
    fn vth_tracks_state_and_offsets() {
        let cfg = DeviceConfig::default();
        let mut f = FeFet::with_offsets(0.02, -0.03);
        f.program(true, &cfg);
        assert!((f.vth(&cfg) - (cfg.vth_low + 0.02)).abs() < 0.05);
        f.program(false, &cfg);
        assert!((f.vth(&cfg) - (cfg.vth_high - 0.03)).abs() < 0.05);
    }

    #[test]
    fn id_vg_separation_between_states() {
        // Fig. 2b: at the read voltage the two states differ by orders of
        // magnitude in current.
        let cfg = DeviceConfig::default();
        let mut lo = FeFet::default();
        lo.program(true, &cfg);
        let mut hi = FeFet::default();
        hi.program(false, &cfg);
        let i_on = lo.id(cfg.v_read, cfg.v_wl, &cfg);
        let i_off = hi.id(cfg.v_read, cfg.v_wl, &cfg);
        assert!(i_on / i_off > 1e3, "on/off = {}", i_on / i_off);
    }

    #[test]
    fn id_monotone_in_vg() {
        let cfg = DeviceConfig::default();
        let mut f = FeFet::default();
        f.program(true, &cfg);
        let mut prev = 0.0;
        for step in 0..40 {
            let vg = -1.0 + 0.08 * step as f64;
            let i = f.id(vg, cfg.v_wl, &cfg);
            assert!(i >= prev, "I_D must be nondecreasing in V_G");
            prev = i;
        }
    }
}
