//! Device layer: behavioral FeFET and 1FeFET1R cell models (paper §2.1).
//!
//! The paper simulates devices with the Preisach FeFET compact model [26] and
//! 45 nm PTM transistors in Spectre. We reproduce the *behaviors the system
//! depends on*:
//!
//! 1. two nonvolatile V_TH states written by gate pulses (Fig. 2a/b),
//! 2. an R-limited ON current that is nearly independent of FeFET V_TH
//!    variation in the 1FeFET1R cell (Fig. 2c, ref [12]),
//! 3. the single-transistor AND gate: a cell conducts only when it stores '1'
//!    *and* its gate is driven high (Fig. 2d),
//! 4. published device-to-device variation statistics (σ_LVT = 54 mV,
//!    σ_HVT = 82 mV, 8 % resistor variability).

mod cell;
mod fefet;
/// ReRAM (1T1R) comparison cell model.
pub mod reram;
mod variation;

pub use cell::{Cell1F1R, CellSample};
pub use fefet::{FeFet, PolarizationState};
pub use reram::Cell1T1R;
pub use variation::VariationSampler;
