//! Device-to-device variation sampling (paper Fig. 7 setup): FeFET V_TH
//! (σ_LVT = 54 mV, σ_HVT = 82 mV [12]), 1R resistor (8 % [13]), MOS size and
//! V_TH (10 % each), and supply voltage (10 %). Each Monte Carlo trial is one
//! fabricated die; all instance offsets are frozen per trial.

use crate::config::{consts, CosimeConfig, VariationConfig};
use crate::util::Rng;

use super::cell::Cell1F1R;

/// Draws frozen per-instance variation for every device class in COSIME.
pub struct VariationSampler {
    cfg: CosimeConfig,
    s_vth_low: f64,
    s_vth_high: f64,
    s_r: f64,
    s_mos_vth: f64,
    s_mos_size: f64,
    s_supply: f64,
}

impl VariationSampler {
    /// Sampler honoring the config's variation switches.
    pub fn new(cfg: &CosimeConfig) -> Self {
        let d = &cfg.device;
        let t = &cfg.translinear;
        let v = &cfg.variation;
        let gate = |on: bool, s: f64| if on { s } else { 0.0 };
        VariationSampler {
            s_vth_low: gate(v.fefet_vth, d.sigma_vth_low),
            s_vth_high: gate(v.fefet_vth, d.sigma_vth_high),
            s_r: gate(v.resistor, d.sigma_r_rel),
            s_mos_vth: gate(v.mos, t.sigma_vth_mismatch),
            s_mos_size: gate(v.mos, t.sigma_wl_rel),
            s_supply: gate(v.supply, v.sigma_supply_rel),
            cfg: cfg.clone(),
        }
    }

    /// Variation toggles in effect.
    pub fn variation(&self) -> &VariationConfig {
        &self.cfg.variation
    }

    /// Sample a fabricated 1FeFET1R cell, programmed to `bit`.
    pub fn cell(&self, bit: bool, rng: &mut Rng) -> Cell1F1R {
        let mut c = Cell1F1R::new(
            rng.normal(0.0, self.s_vth_low),
            rng.normal(0.0, self.s_vth_high),
            rng.normal(0.0, self.s_r).clamp(-0.5, 0.5),
        );
        c.program(bit, &self.cfg.device);
        c
    }

    /// Sample a multiplicative gain error for one subthreshold analog stage
    /// (current mirror leg or translinear loop): V_TH mismatch enters
    /// exponentially (`exp(ΔV_TH/ηV_T)`), W/L mismatch linearly.
    pub fn stage_gain(&self, rng: &mut Rng) -> f64 {
        let n_vt = self.cfg.device.eta * consts::V_T;
        let dvth = rng.normal(0.0, self.s_mos_vth);
        let dsz = rng.normal(0.0, self.s_mos_size).clamp(-0.5, 0.5);
        ((dvth / n_vt).clamp(-3.0, 3.0)).exp() * (1.0 + dsz)
    }

    /// Sample a supply-voltage scale factor (paper: 10 % variation).
    pub fn supply_scale(&self, rng: &mut Rng) -> f64 {
        (1.0 + rng.normal(0.0, self.s_supply)).clamp(0.5, 1.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CosimeConfig;
    use crate::util::{mean, rng, stddev};

    #[test]
    fn disabled_variation_is_deterministic() {
        let mut cfg = CosimeConfig::default();
        cfg.variation = crate::config::VariationConfig {
            fefet_vth: false,
            resistor: false,
            mos: false,
            supply: false,
            sigma_supply_rel: 0.1,
        };
        let s = VariationSampler::new(&cfg);
        let mut r = rng(1);
        for _ in 0..16 {
            assert_eq!(s.stage_gain(&mut r), 1.0);
            assert_eq!(s.supply_scale(&mut r), 1.0);
            let c = s.cell(true, &mut r);
            assert_eq!(c.dr_rel, 0.0);
        }
    }

    #[test]
    fn cell_on_current_spread_matches_resistor_sigma() {
        // With the 1FeFET1R structure the ON-current relative sigma tracks the
        // resistor sigma (~8 %), not the much larger V_TH-induced spread.
        let cfg = CosimeConfig::default();
        let s = VariationSampler::new(&cfg);
        let mut r = rng(2);
        let currents: Vec<f64> =
            (0..4000).map(|_| s.cell(true, &mut r).sample(&cfg.device).i_on).collect();
        let rel_sigma = stddev(&currents) / mean(&currents);
        assert!((rel_sigma - cfg.device.sigma_r_rel).abs() < 0.02, "relative ON sigma {rel_sigma}");
    }

    #[test]
    fn stage_gain_centered_near_one() {
        let cfg = CosimeConfig::default();
        let s = VariationSampler::new(&cfg);
        let mut r = rng(3);
        let gains: Vec<f64> = (0..8000).map(|_| s.stage_gain(&mut r)).collect();
        let m = mean(&gains);
        assert!((m - 1.0).abs() < 0.15, "mean gain {m}");
        let sd = stddev(&gains);
        assert!(sd > 0.05 && sd < 0.8, "gain sigma {sd}");
    }

    #[test]
    fn programmed_bit_survives_variation() {
        let cfg = CosimeConfig::default();
        let s = VariationSampler::new(&cfg);
        let mut r = rng(4);
        for _ in 0..200 {
            assert!(s.cell(true, &mut r).stored());
            assert!(!s.cell(false, &mut r).stored());
        }
    }

    #[test]
    fn supply_scale_spread() {
        let cfg = CosimeConfig::default();
        let s = VariationSampler::new(&cfg);
        let mut r = rng(5);
        let xs: Vec<f64> = (0..4000).map(|_| s.supply_scale(&mut r)).collect();
        assert!((mean(&xs) - 1.0).abs() < 0.01);
        assert!((stddev(&xs) - 0.10).abs() < 0.02);
    }
}
