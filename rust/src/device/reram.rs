//! 1T1R ReRAM cell — the paper's generality claim (§5: "the proposed COSIME
//! design is not limited to FeFET technology, but is rather general and can
//! be applied for other NVMs with access transistors").
//!
//! The peripheral chain (translinear + WTA) only sees row currents, so any
//! cell whose ON current lands in the sensing range works. What changes is
//! the *variation*: ReRAM low-resistance states spread ~30 % device-to-
//! device (filamentary conduction) versus ~8 % for the BEOL resistor of the
//! 1FeFET1R cell [13] — this module quantifies that trade
//! (`examples/variation_study.rs` and the tests below).

use crate::config::DeviceConfig;
use crate::util::Rng;

/// Published-order-of-magnitude ReRAM conductance spreads (e.g. HfOx RRAM).
pub const SIGMA_LRS_REL: f64 = 0.30;
/// High-resistance-state relative conductance spread.
pub const SIGMA_HRS_REL: f64 = 0.50;
/// HRS/LRS resistance window.
pub const ON_OFF_RATIO: f64 = 1e2;

/// A fabricated 1T1R ReRAM cell with frozen conductance variation.
#[derive(Debug, Clone)]
pub struct Cell1T1R {
    stored: bool,
    /// Frozen relative conductance deviation of the programmed state.
    dg_rel: f64,
    /// Current-tuning scale (the Eq. 7 knob — realized here by the read
    /// voltage / access-transistor sizing rather than a programmable R).
    pub tune_scale: f64,
}

impl Cell1T1R {
    /// Sample a fabricated cell programmed to `bit`.
    pub fn sample_new(bit: bool, rng: &mut Rng) -> Self {
        let sigma = if bit { SIGMA_LRS_REL } else { SIGMA_HRS_REL };
        // Lognormal-ish: clamp to keep resistances physical.
        let dg_rel = rng.normal(0.0, sigma).clamp(-0.9, 2.0);
        Cell1T1R { stored: bit, dg_rel, tune_scale: 1.0 }
    }

    /// The stored bit this cell was programmed with.
    pub fn stored(&self) -> bool {
        self.stored
    }

    /// Nominal ON current for a tuning scale (shares the config's wordline
    /// bias and resistance scale so FeFET/ReRAM rows are comparable).
    pub fn i_on_nominal(cfg: &DeviceConfig, tune_scale: f64) -> f64 {
        tune_scale * cfg.v_wl / cfg.r_series
    }

    /// Search current under the AND-gate drive (access transistor gated by
    /// the query bit; conduction set by the programmed conductance).
    pub fn search_current(&self, input_high: bool, cfg: &DeviceConfig) -> f64 {
        if !input_high {
            return 0.0; // access transistor off
        }
        let i_nom = Self::i_on_nominal(cfg, self.tune_scale);
        if self.stored {
            i_nom * (1.0 + self.dg_rel)
        } else {
            i_nom / ON_OFF_RATIO * (1.0 + self.dg_rel)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CosimeConfig, DeviceConfig};
    use crate::device::VariationSampler;
    use crate::util::{mean, rng, stddev};

    #[test]
    fn and_gate_semantics() {
        let cfg = DeviceConfig::default();
        let mut r = rng(1);
        let one = Cell1T1R::sample_new(true, &mut r);
        let zero = Cell1T1R::sample_new(false, &mut r);
        assert_eq!(one.search_current(false, &cfg), 0.0);
        assert_eq!(zero.search_current(false, &cfg), 0.0);
        assert!(one.search_current(true, &cfg) > 10.0 * zero.search_current(true, &cfg));
    }

    #[test]
    fn reram_on_spread_far_exceeds_1fefet1r() {
        // The quantitative content of the generality claim: COSIME works on
        // ReRAM, but the row-current noise floor is ~4x higher than with the
        // R-limited FeFET cell.
        let cfg = CosimeConfig::default();
        let sampler = VariationSampler::new(&cfg);
        let mut r = rng(2);
        let fefet: Vec<f64> =
            (0..3000).map(|_| sampler.cell(true, &mut r).sample(&cfg.device).i_on).collect();
        let reram: Vec<f64> = (0..3000)
            .map(|_| Cell1T1R::sample_new(true, &mut r).search_current(true, &cfg.device))
            .collect();
        let rel = |v: &Vec<f64>| stddev(v) / mean(v);
        let (rf, rr) = (rel(&fefet), rel(&reram));
        assert!(rr > 3.0 * rf, "ReRAM spread {rr:.3} vs 1FeFET1R {rf:.3}");
        assert!((rf - 0.08).abs() < 0.02, "FeFET cell tracks the 8% resistor");
        assert!((rr - 0.30).abs() < 0.05, "ReRAM tracks the 30% LRS sigma");
    }

    #[test]
    fn row_current_averaging_tames_reram_spread() {
        // Rows sum ~hundreds of cells, so the *row* current spread shrinks
        // by sqrt(ones) — why COSIME still functions on noisy NVMs.
        let cfg = DeviceConfig::default();
        let mut r = rng(3);
        let ones = 512usize;
        let rows: Vec<f64> = (0..400)
            .map(|_| {
                (0..ones)
                    .map(|_| Cell1T1R::sample_new(true, &mut r).search_current(true, &cfg))
                    .sum::<f64>()
            })
            .collect();
        let rel = stddev(&rows) / mean(&rows);
        assert!(rel < 0.03, "row-level relative spread {rel:.4} must collapse");
    }

    #[test]
    fn tune_scale_applies() {
        let cfg = DeviceConfig::default();
        let mut r = rng(4);
        let mut c = Cell1T1R::sample_new(true, &mut r);
        let i1 = c.search_current(true, &cfg);
        c.tune_scale = 0.5;
        assert!((c.search_current(true, &cfg) / i1 - 0.5).abs() < 1e-9);
    }
}
