//! 1FeFET1R cell (paper §2.1, refs [12][13]): a FeFET in series with a MΩ
//! BEOL resistor. The resistor limits the ON current, so the cell's ON
//! current is ≈ V/R and nearly independent of FeFET V_TH variation —
//! the property that makes analog row summation robust (Fig. 2c).
//!
//! The cell is the paper's compact AND gate (Fig. 2d): it conducts I_ON only
//! when (stored bit == 1) AND (gate input == 1).

use crate::config::{consts, DeviceConfig};

use super::fefet::FeFet;

/// A fabricated 1FeFET1R cell instance with frozen variation.
#[derive(Debug, Clone)]
pub struct Cell1F1R {
    /// The cell's FeFET (access + storage).
    pub fefet: FeFet,
    /// Relative resistor deviation, frozen at fabrication (σ = 8 % [13]).
    pub dr_rel: f64,
    /// Current-tuning scale applied via the programmable 1R (Eq. 7):
    /// `i_on_nominal = tune_scale * v_wl / r_series`.
    pub tune_scale: f64,
}

/// The currents a cell can contribute during a search, fully characterized.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellSample {
    /// Current when selected (stored 1, gate high) (A).
    pub i_on: f64,
    /// Current when deselected-by-input (stored 1, gate low) (A).
    pub i_gate_off: f64,
    /// Current when storing 0 under a high gate (A) — high-V_TH leakage.
    pub i_store_off: f64,
}

impl Cell1F1R {
    /// Build a cell with explicit frozen variation offsets.
    pub fn new(dvth_low: f64, dvth_high: f64, dr_rel: f64) -> Self {
        Cell1F1R { fefet: FeFet::with_offsets(dvth_low, dvth_high), dr_rel, tune_scale: 1.0 }
    }

    /// Nominal (variation-free) ON current for a given tuning scale (A).
    pub fn i_on_nominal(cfg: &DeviceConfig, tune_scale: f64) -> f64 {
        tune_scale * cfg.v_wl / cfg.r_series
    }

    /// Program the stored bit through the FeFET write path.
    pub fn program(&mut self, bit: bool, cfg: &DeviceConfig) {
        self.fefet.program(bit, cfg);
    }

    /// Stored bit as read back from the polarization state.
    pub fn stored(&self) -> bool {
        self.fefet.state().stores_one()
    }

    /// Characterize the cell's search-time currents.
    ///
    /// * ON branch: R-limited. `I ≈ V_WL/(R(1+δR))`, so `ΔI/I ≈ -δR` — the
    ///   FeFET V_TH variation cancels (paper's key 1FeFET1R claim [12]).
    /// * Gate-off branch: the FeFET gate sits at 0 V, far below low V_TH + read
    ///   margin ⇒ subthreshold-suppressed.
    /// * Store-off branch: high-V_TH device under the read voltage; leakage
    ///   depends exponentially on the high-V_TH variation (σ_HVT = 82 mV).
    pub fn sample(&self, cfg: &DeviceConfig) -> CellSample {
        let i_nom = Self::i_on_nominal(cfg, self.tune_scale);
        let n_vt = cfg.eta * consts::V_T;

        // ON: series R dominates; small residual V_TH sensitivity through the
        // FeFET channel resistance (second-order, ~1e-2 of the R term).
        let r_eff = cfg.r_series * (1.0 + self.dr_rel);
        let channel_factor = 1.0 + 0.01 * (-self.fefet.dvth_low / n_vt).tanh();
        let i_on = self.tune_scale * cfg.v_wl / r_eff * channel_factor;

        // Gate low, stored 1: overdrive = 0 - (vth_low + δ).
        let vth_lo = cfg.vth_low + self.fefet.dvth_low;
        let i_gate_off = (i_nom * ((-(cfg.v_read) - vth_lo + cfg.vth_low) / n_vt).exp())
            .min(i_nom * cfg.off_on_ratio);

        // Gate high, stored 0: overdrive = v_read - (vth_high + δ).
        let dvth = self.fefet.dvth_high;
        let i_store_off = i_nom * cfg.off_on_ratio * (-dvth / n_vt).exp().min(1e3);

        CellSample { i_on, i_gate_off, i_store_off }
    }

    /// Current contributed during a search given the stored bit and the gate
    /// input bit — the AND-gate truth table with analog leakage.
    pub fn search_current(&self, input_high: bool, cfg: &DeviceConfig) -> f64 {
        let s = self.sample(cfg);
        match (self.stored(), input_high) {
            (true, true) => s.i_on,
            (true, false) => s.i_gate_off,
            (false, true) => s.i_store_off,
            (false, false) => 0.0, // gate grounded, high V_TH: negligible
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn programmed(bit: bool) -> (Cell1F1R, DeviceConfig) {
        let cfg = DeviceConfig::default();
        let mut c = Cell1F1R::new(0.0, 0.0, 0.0);
        c.program(bit, &cfg);
        (c, cfg)
    }

    #[test]
    fn and_gate_truth_table() {
        let (one, cfg) = programmed(true);
        let (zero, _) = programmed(false);
        let i_nom = Cell1F1R::i_on_nominal(&cfg, 1.0);
        assert!((one.search_current(true, &cfg) - i_nom).abs() / i_nom < 0.02);
        assert!(one.search_current(false, &cfg) < i_nom * 1e-3);
        assert!(zero.search_current(true, &cfg) < i_nom * 1e-3);
        assert_eq!(zero.search_current(false, &cfg), 0.0);
    }

    #[test]
    fn on_current_insensitive_to_vth_variation() {
        // The 1FeFET1R claim: 3σ V_TH shift moves I_ON by <5 %.
        let cfg = DeviceConfig::default();
        let mut a = Cell1F1R::new(0.0, 0.0, 0.0);
        let mut b = Cell1F1R::new(3.0 * cfg.sigma_vth_low, 0.0, 0.0);
        a.program(true, &cfg);
        b.program(true, &cfg);
        let (ia, ib) = (a.sample(&cfg).i_on, b.sample(&cfg).i_on);
        assert!((ia - ib).abs() / ia < 0.05, "ΔI/I = {}", (ia - ib).abs() / ia);
    }

    #[test]
    fn on_current_tracks_resistor_variation() {
        // ΔI/I ≈ -ΔR/R (paper §2.1).
        let cfg = DeviceConfig::default();
        let mut a = Cell1F1R::new(0.0, 0.0, 0.0);
        let mut b = Cell1F1R::new(0.0, 0.0, 0.08);
        a.program(true, &cfg);
        b.program(true, &cfg);
        let (ia, ib) = (a.sample(&cfg).i_on, b.sample(&cfg).i_on);
        let rel = (ib - ia) / ia;
        assert!((rel + 0.08 / 1.08).abs() < 0.01, "rel = {rel}");
    }

    #[test]
    fn tune_scale_scales_current_linearly() {
        // Eq. 7: scaling rows by N tunes per-cell current by 1/N.
        let cfg = DeviceConfig::default();
        let mut c = Cell1F1R::new(0.0, 0.0, 0.0);
        c.program(true, &cfg);
        let i1 = c.search_current(true, &cfg);
        c.tune_scale = 0.25;
        let i2 = c.search_current(true, &cfg);
        assert!((i2 / i1 - 0.25).abs() < 1e-9);
    }

    #[test]
    fn store_off_leakage_grows_with_low_vth_tail() {
        // A high-V_TH device whose V_TH came out low leaks more — this is the
        // variation channel that matters for false dot-product counts.
        let cfg = DeviceConfig::default();
        let mut nom = Cell1F1R::new(0.0, 0.0, 0.0);
        let mut low_tail = Cell1F1R::new(0.0, -cfg.sigma_vth_high, 0.0);
        nom.program(false, &cfg);
        low_tail.program(false, &cfg);
        assert!(
            low_tail.sample(&cfg).i_store_off > nom.sample(&cfg).i_store_off,
            "lower high-V_TH must leak more"
        );
    }
}
