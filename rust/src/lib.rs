//! # COSIME — FeFET-based Associative Memory for In-Memory Cosine Similarity Search
//!
//! Full-system reproduction of Liu et al., *COSIME: FeFET based Associative Memory
//! for In-Memory Cosine Similarity Search*, ICCAD 2022.
//!
//! The crate is organized bottom-up, mirroring the paper's stack:
//!
//! * [`device`] — FeFET / 1FeFET1R device models with device-to-device variation
//!   (the paper's Preisach + PTM substrate, solved behaviorally instead of SPICE).
//! * [`circuit`] — subthreshold analog building blocks: translinear `X²/Y` loop
//!   (paper §3.3), current mirrors, and the Lazzaro O(N) winner-take-all circuit
//!   with a transient ODE integrator (paper §3.4–3.5).
//! * [`am`] — array-level associative-memory engines: the analog COSIME engine
//!   (device + circuit backed), a bit-exact digital engine, and the
//!   Hamming / approximate-cosine baseline AMs the paper compares against.
//!   [`am::kernel`] is the batched, allocation-free search-kernel interface
//!   (query blocks + bounded top-k selectors) every layer above serves with;
//!   [`am::store`] is the mutable class-vector store (write-verified
//!   insert/update/delete + snapshot persistence for warm starts).
//! * [`energy`] — energy / latency / area accounting calibrated to Table 1.
//! * [`baselines`] — GPU cost model (GTX 1080) and published AM comparison rows.
//! * [`hdc`] — hyperdimensional-computing application layer (paper §4.2):
//!   encoder, single-pass trainer, synthetic datasets with Table 2 shapes.
//! * [`coordinator`] — the L3 serving engine: request router, dynamic batcher,
//!   tile manager with hierarchical winner merge (live-updatable, epoch
//!   coherent), the admin plane for write-verified class updates, metrics,
//!   backpressure.
//! * [`server`] — the L4 networked frontend (`cosimed`): length-prefixed
//!   binary wire protocol, threaded TCP server with per-connection bounded
//!   pipelining, blocking client library, and scatter-gather sharding
//!   across independent coordinator stacks
//!   (`cosime serve --listen ADDR --shards S`).
//! * [`runtime`] — PJRT/XLA runtime that loads AOT-lowered JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) and runs them from the Rust hot path.
//! * [`perf`] — the measured-performance rail: `cosime bench` regenerates
//!   schema-versioned `BENCH_kernel.json` / `BENCH_serving.json` at the repo
//!   root (per-dispatch-path GB/s + Melems/s, serving p50/p99 + pipelined
//!   throughput), validated in CI.
//! * [`repro`] — regeneration harnesses for every table and figure in the paper.
//!
//! * [`lint`] — the in-crate invariant linter behind `cosime lint`:
//!   SAFETY-comment, no-panic, hot-path-allocation, wire/config
//!   exhaustiveness, lock-order, and epoch-discipline rules over the
//!   whole tree (tier-1 gated), plus the `--waivers` audit report.
//!
//! See `rust/README.md` for the kernel API walkthrough, the cargo feature
//! flags (notably the off-by-default `xla` runtime backend), and the
//! experiment index.

// Every `unsafe` operation inside an `unsafe fn` must be wrapped in its own
// `unsafe {}` block (each with a `// SAFETY:` comment enforced by
// `cosime lint`), and every public item must be documented.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_docs)]

/// Associative-memory engines: digital exact/Hamming/approx-cosine/dot.
pub mod am;
/// Published accelerator numbers used for comparison tables.
pub mod baselines;
/// Analog circuit models: translinear core, WTA, mirrors, waveforms.
pub mod circuit;
/// TOML config loading and the `cosime.toml` schema.
pub mod config;
/// Tile manager, batching service, metrics — the serving data plane.
pub mod coordinator;
/// FeFET/ReRAM device models and variation sampling.
pub mod device;
/// Energy/latency accounting shared by the repro figures.
pub mod energy;
/// Hyperdimensional-computing workload: encoder, trainer, datasets.
pub mod hdc;
/// In-crate invariant linter behind `cosime lint`.
pub mod lint;
/// Performance counters and flamegraph-friendly timers.
pub mod perf;
/// Paper figure/table reproductions (`cosime repro`).
pub mod repro;
/// XLA/PjRt artifact plumbing (stubbed unless the `xla` feature is on).
pub mod runtime;
/// Networked serving: wire protocol, servers, client, sharding router.
pub mod server;
/// Support code: bitvectors, stats, JSON, TOML, CLI, RNG, sync helpers.
pub mod util;

pub use config::CosimeConfig;
