//! # COSIME — FeFET-based Associative Memory for In-Memory Cosine Similarity Search
//!
//! Full-system reproduction of Liu et al., *COSIME: FeFET based Associative Memory
//! for In-Memory Cosine Similarity Search*, ICCAD 2022.
//!
//! The crate is organized bottom-up, mirroring the paper's stack:
//!
//! * [`device`] — FeFET / 1FeFET1R device models with device-to-device variation
//!   (the paper's Preisach + PTM substrate, solved behaviorally instead of SPICE).
//! * [`circuit`] — subthreshold analog building blocks: translinear `X²/Y` loop
//!   (paper §3.3), current mirrors, and the Lazzaro O(N) winner-take-all circuit
//!   with a transient ODE integrator (paper §3.4–3.5).
//! * [`am`] — array-level associative-memory engines: the analog COSIME engine
//!   (device + circuit backed), a bit-exact digital engine, and the
//!   Hamming / approximate-cosine baseline AMs the paper compares against.
//!   [`am::kernel`] is the batched, allocation-free search-kernel interface
//!   (query blocks + bounded top-k selectors) every layer above serves with;
//!   [`am::store`] is the mutable class-vector store (write-verified
//!   insert/update/delete + snapshot persistence for warm starts).
//! * [`energy`] — energy / latency / area accounting calibrated to Table 1.
//! * [`baselines`] — GPU cost model (GTX 1080) and published AM comparison rows.
//! * [`hdc`] — hyperdimensional-computing application layer (paper §4.2):
//!   encoder, single-pass trainer, synthetic datasets with Table 2 shapes.
//! * [`coordinator`] — the L3 serving engine: request router, dynamic batcher,
//!   tile manager with hierarchical winner merge (live-updatable, epoch
//!   coherent), the admin plane for write-verified class updates, metrics,
//!   backpressure.
//! * [`server`] — the L4 networked frontend (`cosimed`): length-prefixed
//!   binary wire protocol, threaded TCP server with per-connection bounded
//!   pipelining, blocking client library, and scatter-gather sharding
//!   across independent coordinator stacks
//!   (`cosime serve --listen ADDR --shards S`).
//! * [`runtime`] — PJRT/XLA runtime that loads AOT-lowered JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) and runs them from the Rust hot path.
//! * [`perf`] — the measured-performance rail: `cosime bench` regenerates
//!   schema-versioned `BENCH_kernel.json` / `BENCH_serving.json` at the repo
//!   root (per-dispatch-path GB/s + Melems/s, serving p50/p99 + pipelined
//!   throughput), validated in CI.
//! * [`repro`] — regeneration harnesses for every table and figure in the paper.
//!
//! See `rust/README.md` for the kernel API walkthrough, the cargo feature
//! flags (notably the off-by-default `xla` runtime backend), and the
//! experiment index.

pub mod am;
pub mod baselines;
pub mod circuit;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod energy;
pub mod hdc;
pub mod perf;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod util;

pub use config::CosimeConfig;
