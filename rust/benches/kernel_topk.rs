//! Bench: the batched, allocation-free SearchKernel path vs the seed-shaped
//! single-query search loop, at the serving geometry (4096×1024, 4 tiles).
//!
//! All block-path buffers (query block, tile scratch, selectors) are
//! created once and reused across iterations — the steady-state serving
//! loop's zero-per-query-allocation shape. The closing summary compares
//! queries/s of the batched kernel against the single-query path.
//!
//! Units: every case reports through the shared `bench_gbps` helper with the
//! convention used crate-wide (see `BENCH_kernel.json`): bytes = the unique
//! packed-matrix footprint streamed per iteration, elems = queries scored.
//! Cache-blocked batching shows up directly as higher GB/s at equal bytes.

use cosime::am::{AmEngine, BlockSink, BlockTopK, DigitalExactEngine, QueryBlock, SearchScratch};
use cosime::coordinator::TileManager;
use cosime::util::bench::Bench;
use cosime::util::{rng, BitVec};

fn main() {
    let (rows, dims, batch) = (4096usize, 1024usize, 64usize);
    let mut r = rng(1);
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let queries: Vec<BitVec> = (0..batch).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();

    let engine = DigitalExactEngine::new(words.clone());
    let tm = TileManager::build(words, 1024, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })
    .unwrap();

    let mut b = Bench::new();
    // Unique packed-matrix bytes one full scan streams (the GB/s basis).
    let matrix_bytes = (rows * dims.div_ceil(64) * 8) as f64;

    // Seed-shaped path: one fused search per call, serial.
    let mut i = 0usize;
    let single_engine = b
        .bench_gbps("engine/search x1 (seed path)", 1.0, matrix_bytes, || {
            i = (i + 1) % batch;
            engine.search(&queries[i])
        })
        .throughput()
        .unwrap();

    // Batched block kernel on the flat engine (same serial row scan, but
    // amortized dispatch + reused buffers).
    let mut block = QueryBlock::new(dims);
    block.repack(&queries);
    let mut scratch = SearchScratch::new();
    let mut out = BlockTopK::new();
    let block_engine = b
        .bench_gbps(&format!("engine/search_block x{batch}/k=1"), batch as f64, matrix_bytes, || {
            out.reset(batch, 1);
            engine.search_block(block.view(), 0, &mut scratch, BlockSink::TopK(out.selectors_mut()));
        })
        .throughput()
        .unwrap();

    // Deep-k on the flat engine: the fused selector instead of a sort.
    b.bench_gbps(&format!("engine/search_block x{batch}/k=10"), batch as f64, matrix_bytes, || {
        out.reset(batch, 10);
        engine.search_block(block.view(), 0, &mut scratch, BlockSink::TopK(out.selectors_mut()));
    });

    // Tile manager: serial single-query merge vs the parallel tile×batch
    // kernel over reused scratch.
    let q_one = queries[0].clone();
    let single_tiles = b
        .bench_gbps("tiles/search x1 (hierarchical k=1)", 1.0, matrix_bytes, || tm.search(&q_one))
        .throughput()
        .unwrap();
    let mut tile_scratch = tm.scratch();
    let mut tile_out = BlockTopK::new();
    let block_tiles = b
        .bench_gbps(&format!("tiles/search_block x{batch}/k=1"), batch as f64, matrix_bytes, || {
            tm.search_block(block.view(), 1, &mut tile_scratch, &mut tile_out)
        })
        .throughput()
        .unwrap();
    b.bench_gbps(&format!("tiles/search_block x{batch}/k=10"), batch as f64, matrix_bytes, || {
        tm.search_block(block.view(), 10, &mut tile_scratch, &mut tile_out)
    });
    b.bench_gbps(&format!("tiles/search_block x{batch}/k=100"), batch as f64, matrix_bytes, || {
        tm.search_block(block.view(), 100, &mut tile_scratch, &mut tile_out)
    });

    b.report("SearchKernel — batched block top-k vs single-query search (queries/s)");

    println!(
        "\nbatched vs single-query throughput:\n\
         \x20 flat engine: {:.2}x ({:.0} vs {:.0} queries/s)\n\
         \x20 tiled      : {:.2}x ({:.0} vs {:.0} queries/s)",
        block_engine / single_engine,
        block_engine,
        single_engine,
        block_tiles / single_tiles,
        block_tiles,
        single_tiles,
    );
    if block_tiles >= single_tiles && block_engine >= 0.9 * single_engine {
        println!("batched kernel throughput >= seed single-query path: OK");
    } else {
        println!("WARNING: batched kernel slower than single-query path on this host");
    }
}
