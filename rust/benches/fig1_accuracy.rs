//! Bench: Fig. 1 — metric-comparison evaluation cost (NN classification and
//! few-shot episodes) plus the regenerated accuracy tables.

use cosime::hdc::{
    cosine_engine, evaluate_accuracy, few_shot_accuracy, hamming_engine, Dataset, DatasetSpec,
    FewShotSpec, SyntheticParams, TrainConfig,
};
use cosime::util::bench::Bench;

fn main() {
    let ds = Dataset::synthetic(
        DatasetSpec::Ucihar,
        SyntheticParams { subsample: 0.02, ..Default::default() },
        1,
    );
    let mut b = Bench::new();
    let cfg = TrainConfig { dims: 512, epochs: 1, ..Default::default() };
    b.bench("fig1a/evaluate/cosine/D=512", || evaluate_accuracy(&ds, cfg, cosine_engine));
    b.bench("fig1a/evaluate/hamming/D=512", || evaluate_accuracy(&ds, cfg, hamming_engine));
    let spec = FewShotSpec { ways: 5, shots: 5, queries: 4, episodes: 10, dims: 512, seed: 2 };
    b.bench("fig1b/few-shot/cosine/10-episodes", || few_shot_accuracy(&ds, spec, cosine_engine));
    b.report("Fig. 1 workload — evaluation benchmarks");
    println!();
    cosime::repro::fig1::run(0.05, Some("results")).expect("fig1");
}
