//! Bench: the networked frontend — wire-protocol codec costs and loopback
//! round trips through a real `cosimed` TCP server (strict request/response
//! vs pipelined, single query vs batched frames, 1 vs 2 shards, threaded
//! vs event-loop I/O engine).

use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::{CosimeConfig, IoMode};
use cosime::server::protocol::{decode_search_request, encode_search_request};
use cosime::server::{Client, CosimeServer, ShardRouter};
use cosime::util::bench::Bench;
use cosime::util::{rng, BitVec};
use std::time::Duration;

fn start_server(rows: usize, dims: usize, shards: usize, io: IoMode) -> CosimeServer {
    let mut cfg = CosimeConfig::default();
    cfg.server.listen = "127.0.0.1:0".to_string();
    cfg.server.io = io;
    cfg.coordinator.workers = 2;
    let mut r = rng(17);
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let router = ShardRouter::build(&cfg, shards, 256, words, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })
    .unwrap();
    CosimeServer::serve(&cfg.server, router).unwrap()
}

fn main() {
    let mut b = Bench::new();
    let mut r = rng(23);

    // Codec microbenchmarks: what a frame costs before any socket.
    let queries: Vec<BitVec> = (0..16).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
    let payload = encode_search_request(&queries, 8);
    b.bench_throughput("protocol/encode/16q x 1024b", 16.0, || {
        encode_search_request(&queries, 8)
    });
    b.bench_throughput("protocol/decode/16q x 1024b", 16.0, || {
        decode_search_request(&payload).unwrap()
    });

    // Loopback round trips: the full stack (codec + TCP + batcher +
    // kernel), on both I/O engines — same wire protocol, same backend.
    for io in [IoMode::Threaded, IoMode::EventLoop] {
        for shards in [1usize, 2] {
            let tag = io.as_str();
            let server = start_server(2048, 1024, shards, io);
            let mut client =
                Client::connect_retry(server.local_addr(), 10, Duration::from_millis(20))
                    .unwrap();
            let q = BitVec::random(1024, 0.5, &mut r);
            b.bench_throughput(&format!("tcp-{tag}/roundtrip/1q/k1/{shards}-shard"), 1.0, || {
                client.search_topk(&q, 1).unwrap()
            });
            let batch: Vec<BitVec> =
                (0..16).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
            b.bench_throughput(
                &format!("tcp-{tag}/roundtrip/16q/k4/{shards}-shard"),
                16.0,
                || client.search_batch(&batch, 4).unwrap(),
            );
            // Pipelined: 8 frames of 16 queries in flight per window.
            b.bench_throughput(
                &format!("tcp-{tag}/pipelined/8x16q/k4/{shards}-shard"),
                128.0,
                || {
                    let mut pipe = client.pipeline();
                    for _ in 0..8 {
                        pipe.search_batch(&batch, 4).unwrap();
                    }
                    pipe.finish().unwrap()
                },
            );
            drop(client);
            server.shutdown();
        }
    }

    b.report("server wire + loopback");
}
