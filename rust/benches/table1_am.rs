//! Bench: Table 1 — per-search cost of each AM realization at the paper's
//! 256×256 geometry, plus the modeled fJ/bit / ns / mm² table itself.
//!
//! Wall-clock numbers here are *simulator* throughput (how fast this crate
//! searches); the paper-comparable metrics come from the calibrated energy
//! model printed below.

use cosime::am::analog::AnalogCosimeEngine;
use cosime::am::{AmEngine, ApproxCosineEngine, DigitalExactEngine, DotEngine, HammingEngine};
use cosime::config::CosimeConfig;
use cosime::runtime::{RuntimeHandle, XlaAmEngine};
use cosime::util::bench::Bench;
use cosime::util::{rng, BitVec};

fn main() {
    let (rows, dims) = (256usize, 256usize);
    let mut r = rng(1);
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let queries: Vec<BitVec> = (0..64).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let cfg = CosimeConfig::default();

    let mut b = Bench::new();
    let engines: Vec<Box<dyn AmEngine>> = vec![
        Box::new(DigitalExactEngine::new(words.clone())),
        Box::new(HammingEngine::new(words.clone())),
        Box::new(ApproxCosineEngine::new(words.clone())),
        Box::new(DotEngine::new(words.clone())),
        Box::new(AnalogCosimeEngine::nominal(&cfg, words.clone())),
    ];
    for e in &engines {
        let mut i = 0usize;
        b.bench_throughput(&format!("search/{}/256x256", e.name()), 1.0, || {
            i = (i + 1) % queries.len();
            e.search(&queries[i])
        });
    }

    if let Ok(rt) = RuntimeHandle::spawn("artifacts") {
        if let Ok(x) = XlaAmEngine::new(&rt, "cosime_search_r256_d256_b8", &words) {
            let mut i = 0usize;
            b.bench_throughput("search/xla-batch8/256x256", 8.0, || {
                i = (i + 8) % 64;
                x.search_batch(&queries[i..i + 8.min(64 - i)])
            });
        }
    }

    b.report("Table 1 workload — simulator search timings");
    println!();
    cosime::repro::table1::run().expect("table1");
}
