//! Bench: the L3 serving engine — end-to-end service throughput under
//! concurrent load across batching policies, plus batcher and tile-kernel
//! microbenchmarks. This is the hot path the performance pass tracks.

use cosime::am::{AmEngine, BlockTopK, DigitalExactEngine, QueryBlock};
use cosime::config::CosimeConfig;
use cosime::coordinator::{AmService, Batcher, TileManager};
use cosime::util::bench::Bench;
use cosime::util::{rng, BitVec};
use std::time::{Duration, Instant};

fn service_throughput(rows: usize, dims: usize, workers: usize, max_batch: usize, n: usize) -> f64 {
    let mut cfg = CosimeConfig::default();
    cfg.coordinator.workers = workers;
    cfg.coordinator.max_batch = max_batch;
    let mut r = rng(7);
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let tiles = TileManager::build(words, 256, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })
    .unwrap();
    let svc = AmService::start(&cfg.coordinator, tiles);
    let clients = 8u64;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let svc = svc.clone();
            s.spawn(move || {
                let mut r = rng(100 + c);
                for _ in 0..n as u64 / clients {
                    let q = BitVec::random(dims, 0.5, &mut r);
                    let _ = svc.search_with_retry(q, 50);
                }
            });
        }
    });
    let tput = svc.metrics().completed as f64 / t0.elapsed().as_secs_f64();
    svc.shutdown();
    tput
}

fn main() {
    let mut b = Bench::new();

    // Batcher microbenchmarks: submit + drain round trip.
    let batcher: Batcher<u64> = Batcher::new(64, Duration::from_micros(1), 1 << 16);
    b.bench("batcher/submit+drain", || {
        batcher.submit(1).unwrap();
        batcher.next_batch()
    });

    // Tile merge cost.
    let mut r = rng(3);
    let words: Vec<BitVec> = (0..1024).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
    let tiles = TileManager::build(words, 256, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })
    .unwrap();
    let q = BitVec::random(1024, 0.5, &mut r);
    // Shared units (see util::bench::bench_gbps): elems = row scores produced,
    // bytes = the unique packed-matrix footprint streamed per iteration.
    let matrix_bytes = (1024 * 1024_usize.div_ceil(64) * 8) as f64;
    b.bench_gbps("tiles/search/1024x1024/4-tiles", 1024.0, matrix_bytes, || tiles.search(&q));
    let batch: Vec<BitVec> = (0..32).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
    b.bench_gbps("tiles/search_batch32/1024x1024", 32.0 * 1024.0, matrix_bytes, || {
        tiles.search_batch(&batch)
    });
    // The allocation-free serving shape: reused block + scratch + selectors.
    let mut block = QueryBlock::new(1024);
    block.repack(&batch);
    let mut scratch = tiles.scratch();
    let mut out = BlockTopK::new();
    for k in [1usize, 8, 32] {
        b.bench_gbps(
            &format!("tiles/search_block32/k={k}/1024x1024"),
            32.0 * 1024.0,
            matrix_bytes,
            || tiles.search_block(block.view(), k, &mut scratch, &mut out),
        );
    }

    b.report("Coordinator microbenchmarks");

    println!("\n== service throughput (8 clients, 4096x1024 store) ==");
    println!("{:>8} {:>10} {:>16}", "workers", "max_batch", "queries/s");
    for (workers, max_batch) in [(1, 1), (1, 32), (2, 32), (4, 32), (4, 64), (8, 64)] {
        let tput = service_throughput(4096, 1024, workers, max_batch, 6000);
        println!("{workers:>8} {max_batch:>10} {tput:>16.0}");
    }
}
