//! Bench: Fig. 6 — scaling of the search across rows and wordlength, on
//! both the digital hot path (what the coordinator serves) and the analog
//! transient simulator (what regenerates the figure), plus the figure's own
//! modeled energy/delay table.

use cosime::am::analog::AnalogCosimeEngine;
use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::circuit::Wta;
use cosime::config::CosimeConfig;
use cosime::util::bench::Bench;
use cosime::util::{rng, BitVec};

fn main() {
    let cfg = CosimeConfig::default();
    let mut b = Bench::new();

    // Digital search scaling in rows (dims = 1024).
    for rows in [64usize, 256, 1024, 4096] {
        let mut r = rng(rows as u64);
        let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
        let e = DigitalExactEngine::new(words);
        let q = BitVec::random(1024, 0.5, &mut r);
        b.bench_throughput(&format!("digital/rows={rows}/d=1024"), rows as f64, || e.search(&q));
    }

    // Digital search scaling in dims (rows = 256).
    for dims in [64usize, 256, 1024, 4096] {
        let mut r = rng(dims as u64 + 17);
        let words: Vec<BitVec> = (0..256).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
        let e = DigitalExactEngine::new(words);
        let q = BitVec::random(dims, 0.5, &mut r);
        b.bench_throughput(&format!("digital/rows=256/d={dims}"), 256.0, || e.search(&q));
    }

    // Analog static search (row currents + translinear + static WTA).
    for rows in [64usize, 256] {
        let mut r = rng(rows as u64 + 31);
        let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
        let e = AnalogCosimeEngine::nominal(&cfg, words);
        let q = BitVec::random(1024, 0.5, &mut r);
        b.bench_throughput(&format!("analog-static/rows={rows}/d=1024"), rows as f64, || {
            e.search(&q)
        });
    }

    // WTA transient solve cost vs rail count (the fig6 inner loop).
    for rails in [16usize, 64, 256] {
        let wta = Wta::new(cfg.wta.clone());
        let mut inputs = vec![0.24e-6; rails];
        inputs[rails / 2] = 0.3e-6;
        b.bench(&format!("wta-transient/rails={rails}"), || wta.settle(&inputs, false));
    }

    b.report("Fig. 6 workload — scaling benchmarks");
    println!();
    cosime::repro::fig6::run("both", Some("results")).expect("fig6");
}
