//! Bench: Fig. 9 — the HDC case-study pipeline: encode throughput, training
//! time, inference through each engine, and the COSIME-vs-GPU ratio table.

use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::hdc::{
    AnyEncoder, Dataset, DatasetSpec, EncoderKind, HdcModel, SyntheticParams, TrainConfig,
};
use cosime::runtime::{RuntimeHandle, XlaAmEngine};
use cosime::util::bench::Bench;

fn main() {
    let ds = Dataset::synthetic(
        DatasetSpec::Isolet,
        SyntheticParams { subsample: 0.05, ..Default::default() },
        1,
    );
    let mut b = Bench::new();

    // Encoders.
    for (name, kind) in [
        ("level", EncoderKind::Level { spread: 2.0 }),
        ("random-projection", EncoderKind::RandomProjection { threshold_scale: 0.0 }),
    ] {
        let enc = AnyEncoder::build(kind, 1024, ds.features, 3);
        let x = &ds.train_x[0];
        b.bench_throughput(&format!("encode/{name}/D=1024"), 1.0, || enc.encode(x));
    }

    // Training (single pass, D=512 on the subsampled set).
    b.bench("train/single-pass/D=512", || {
        HdcModel::train(&ds, TrainConfig { dims: 512, epochs: 0, ..Default::default() })
    });

    // Inference through engines.
    let model = HdcModel::train(&ds, TrainConfig { dims: 1024, epochs: 1, ..Default::default() });
    let hvs = model.class_hypervectors();
    let digital = DigitalExactEngine::new(hvs.clone());
    let h = model.encoder.encode(&ds.test_x[0]);
    b.bench_throughput("infer/digital/K=26/D=1024", 1.0, || digital.search(&h));

    if let Ok(rt) = RuntimeHandle::spawn("artifacts") {
        if let Ok(x) = XlaAmEngine::new(&rt, "cosime_search_r256_d1024_b8", &hvs) {
            b.bench_throughput("infer/xla/K=26/D=1024", 1.0, || x.search(&h));
        }
    }

    b.report("Fig. 9 workload — HDC pipeline benchmarks");
    println!();
    cosime::repro::fig9::run_bc(Some("results")).expect("fig9bc");
}
