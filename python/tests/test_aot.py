"""AOT pipeline tests: lowering produces well-formed HLO text + manifest."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_entry_points_cover_every_model_fn():
    names = {n for n, _, _ in aot.entry_points()}
    assert any(n.startswith("cosime_search") for n in names)
    assert any(n.startswith("hamming_search") for n in names)
    assert any(n.startswith("approx_search") for n in names)
    assert any(n.startswith("hdc_encode") for n in names)
    assert any(n.startswith("hdc_infer") for n in names)
    assert any(n.startswith("analog_mc") for n in names)
    assert any(n.startswith("exact_cosine") for n in names)


def test_lower_all_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert len(manifest) == len(aot.entry_points())
        for entry in manifest:
            path = os.path.join(d, entry["file"])
            assert os.path.exists(path), entry["file"]
            text = open(path).read()
            assert text.startswith("HloModule"), entry["name"]
            # ENTRY computation present and returns a tuple (return_tuple=True).
            assert "ENTRY" in text
            assert entry["inputs"], entry["name"]
            assert entry["outputs"], entry["name"]


def test_manifest_shapes_match_entry_specs():
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d)
        manifest = {e["name"]: e for e in json.load(open(os.path.join(d, "manifest.json")))}
    for name, _, args in aot.entry_points():
        entry = manifest[name]
        assert [tuple(i["shape"]) for i in entry["inputs"]] == [a.shape for a in args]


def test_lowered_search_is_pallas_free_hlo():
    # interpret=True must lower to plain HLO ops (no custom-calls the CPU
    # PJRT client cannot run).
    lowered = jax.jit(model.am_search_cosine).lower(
        jax.ShapeDtypeStruct((4, 128), jnp.float32),
        jax.ShapeDtypeStruct((32, 128), jnp.float32),
        jax.ShapeDtypeStruct((32,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into HLO"
