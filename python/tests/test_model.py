"""L2 model-level tests: composed graphs (encode -> search), shape contracts,
and the GPU-comparator computation."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def binary(rng, shape, density=0.5):
    return (rng.random(shape) < density).astype(np.float32)


def test_hdc_infer_composes_encode_and_search():
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((8, 61)).astype(np.float32)
    proj = np.where(rng.random((256, 61)) < 0.5, 1.0, -1.0).astype(np.float32)
    cls = binary(rng, (16, 256))
    y = cls.sum(axis=1)
    idx, score = model.hdc_infer(feats, proj, cls, y)
    h = ref.hdc_encode_ref(feats, proj)
    ridx, rscore = ref.cosine_search_ref(h, cls, y)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(score), np.asarray(rscore), rtol=1e-6)


def test_hdc_infer_classifies_class_prototypes():
    # Inference on noiseless prototypes must return the prototype's row.
    rng = np.random.default_rng(1)
    protos = rng.standard_normal((8, 61)).astype(np.float32)
    proj = np.where(rng.random((256, 61)) < 0.5, 1.0, -1.0).astype(np.float32)
    h = ref.hdc_encode_ref(protos, proj)
    y = h.sum(axis=1)
    idx, _ = model.hdc_infer(protos, proj, h, y)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(8))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_exact_cosine_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((4, 64)).astype(np.float32)
    cls = rng.standard_normal((16, 64)).astype(np.float32)
    idx, score = model.exact_cosine_f32(q, cls)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    cn = cls / np.linalg.norm(cls, axis=1, keepdims=True)
    s = qn @ cn.T
    np.testing.assert_array_equal(np.asarray(idx), s.argmax(axis=1))
    np.testing.assert_allclose(np.asarray(score), s.max(axis=1), rtol=1e-5)


def test_exact_vs_squared_cosine_same_winner_for_binary():
    # For binary vectors the squared-cosine argmax equals the cosine argmax
    # (squaring is monotone on [0, 1]) — the paper's Eq. 2 equivalence.
    rng = np.random.default_rng(2)
    q = binary(rng, (8, 128))
    cls = binary(rng, (32, 128), 0.4)
    y = cls.sum(axis=1)
    sq_idx, _ = model.am_search_cosine(q, cls, y)
    ex_idx, _ = model.exact_cosine_f32(q, cls)
    np.testing.assert_array_equal(np.asarray(sq_idx), np.asarray(ex_idx))


def test_search_variants_shapes():
    rng = np.random.default_rng(3)
    q = binary(rng, (4, 128))
    cls = binary(rng, (32, 128))
    y = cls.sum(axis=1)
    for out in [
        model.am_search_cosine(q, cls, y),
        model.am_search_hamming(q, cls, y),
        model.am_search_approx(q, cls, np.array([8.0], dtype=np.float32)),
    ]:
        idx, score = out
        assert np.asarray(idx).shape == (4,)
        assert np.asarray(score).shape == (4,)
        assert np.asarray(idx).dtype == np.int32
