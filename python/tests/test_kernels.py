"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes, densities and seeds. This is the CORE
correctness signal for the compute the Rust runtime executes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    analog_mc_search,
    approx_cosine_search,
    cosime_scores,
    cosime_search,
    hamming_search,
    hdc_encode,
)
from compile.kernels import ref

SHAPES = st.tuples(
    st.sampled_from([1, 2, 4, 8]),  # batch
    st.sampled_from([8, 16, 32, 64, 128]),  # rows
    st.sampled_from([16, 64, 128, 256]),  # dims
)


def binary(rng, shape, density):
    return (rng.random(shape) < density).astype(np.float32)


# ---------------------------------------------------------------- cosine ----


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, density=st.floats(0.1, 0.9), seed=st.integers(0, 2**31 - 1))
def test_cosime_search_matches_ref(shape, density, seed):
    b, n, d = shape
    rng = np.random.default_rng(seed)
    q = binary(rng, (b, d), 0.5)
    cls = binary(rng, (n, d), density)
    y = cls.sum(axis=1)
    idx, score = cosime_search(q, cls, y, block_rows=min(n, 32))
    ridx, rscore = ref.cosine_search_ref(q, cls, y)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(score), np.asarray(rscore), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_cosime_scores_matrix_matches_ref(seed):
    rng = np.random.default_rng(seed)
    q = binary(rng, (4, 64), 0.5)
    cls = binary(rng, (64, 64), 0.5)
    y = cls.sum(axis=1)
    s = cosime_scores(q, cls, y, block_rows=32)
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(ref.cosine_scores_ref(q, cls, y)), rtol=1e-6
    )


def test_cosime_search_exact_self_match():
    rng = np.random.default_rng(7)
    cls = binary(rng, (32, 128), 0.5)
    y = cls.sum(axis=1)
    idx, score = cosime_search(cls[:8], cls, y, block_rows=16)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(8))
    # Self-match score = X^2/Y = Y (since X = Y for a self-dot).
    np.testing.assert_allclose(np.asarray(score), y[:8], rtol=1e-6)


def test_cosime_block_size_invariance():
    rng = np.random.default_rng(8)
    q = binary(rng, (4, 64), 0.5)
    cls = binary(rng, (64, 64), 0.5)
    y = cls.sum(axis=1)
    results = [
        np.asarray(cosime_search(q, cls, y, block_rows=br)[0]) for br in (8, 16, 32, 64)
    ]
    for r in results[1:]:
        np.testing.assert_array_equal(results[0], r)


def test_cosime_zero_rows_never_win():
    rng = np.random.default_rng(9)
    cls = binary(rng, (16, 32), 0.5)
    cls[3:8] = 0.0  # padding rows
    y = cls.sum(axis=1)
    q = binary(rng, (4, 32), 0.5)
    idx, _ = cosime_search(q, cls, y, block_rows=8)
    assert not np.isin(np.asarray(idx), np.arange(3, 8)).any()


# --------------------------------------------------------------- hamming ----


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
def test_hamming_search_matches_ref(shape, seed):
    b, n, d = shape
    rng = np.random.default_rng(seed)
    q = binary(rng, (b, d), 0.5)
    cls = binary(rng, (n, d), 0.5)
    pc = cls.sum(axis=1)
    idx, score = hamming_search(q, cls, pc, block_rows=min(n, 32))
    ridx, rscore = ref.hamming_search_ref(q, cls)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(score), np.asarray(rscore), rtol=1e-6)


def test_hamming_exact_match_is_zero_distance():
    rng = np.random.default_rng(10)
    cls = binary(rng, (16, 64), 0.5)
    pc = cls.sum(axis=1)
    idx, score = hamming_search(cls[:4], cls, pc, block_rows=16)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(4))
    np.testing.assert_allclose(np.asarray(score), 0.0, atol=1e-6)


# ---------------------------------------------------------------- approx ----


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**31 - 1), nc=st.floats(1.0, 100.0))
def test_approx_search_matches_ref(shape, seed, nc):
    b, n, d = shape
    rng = np.random.default_rng(seed)
    q = binary(rng, (b, d), 0.5)
    cls = binary(rng, (n, d), 0.5)
    ncv = np.array([nc], dtype=np.float32)
    idx, score = approx_cosine_search(q, cls, ncv, block_rows=min(n, 32))
    ridx, rscore = ref.approx_cosine_search_ref(q, cls, np.float32(nc))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(score), np.asarray(rscore), rtol=1e-5)


# ---------------------------------------------------------------- encode ----


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    n=st.sampled_from([7, 32, 61, 128]),
    dims=st.sampled_from([64, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hdc_encode_matches_ref(b, n, dims, seed):
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((b, n)).astype(np.float32)
    proj = np.where(rng.random((dims, n)) < 0.5, 1.0, -1.0).astype(np.float32)
    h = hdc_encode(feats, proj, block_d=min(dims, 64))
    np.testing.assert_array_equal(np.asarray(h), ref.hdc_encode_ref(feats, proj))


def test_hdc_encode_output_is_binary():
    rng = np.random.default_rng(11)
    feats = rng.standard_normal((4, 33)).astype(np.float32)
    proj = np.where(rng.random((128, 33)) < 0.5, 1.0, -1.0).astype(np.float32)
    h = np.asarray(hdc_encode(feats, proj, block_d=64))
    assert set(np.unique(h)) <= {0.0, 1.0}


# ------------------------------------------------------------------- MC -----


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), trials=st.sampled_from([1, 4, 16]))
def test_analog_mc_matches_ref(seed, trials):
    rng = np.random.default_rng(seed)
    q = binary(rng, (4, 64), 0.5)
    cls = binary(rng, (16, 64), 0.5)
    y = cls.sum(axis=1)
    gains = (1.0 + 0.12 * rng.standard_normal((trials, 16))).astype(np.float32)
    w = analog_mc_search(q, cls, y, gains)
    rw = ref.analog_mc_search_ref(q, cls, y, gains)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(rw))


def test_analog_mc_unit_gains_equal_nominal():
    rng = np.random.default_rng(12)
    q = binary(rng, (4, 64), 0.5)
    cls = binary(rng, (16, 64), 0.5)
    y = cls.sum(axis=1)
    gains = np.ones((3, 16), dtype=np.float32)
    w = np.asarray(analog_mc_search(q, cls, y, gains))
    nom, _ = ref.cosine_search_ref(q, cls, y)
    for t in range(3):
        np.testing.assert_array_equal(w[t], np.asarray(nom))


# -------------------------------------------------- degenerate edge cases ---


@pytest.mark.parametrize("density", [0.0, 1.0])
def test_extreme_density_does_not_nan(density):
    rng = np.random.default_rng(13)
    q = binary(rng, (2, 32), 0.5)
    cls = np.full((8, 32), density, dtype=np.float32)
    y = cls.sum(axis=1)
    idx, score = cosime_search(q, cls, y, block_rows=8)
    assert np.isfinite(np.asarray(score)).all()
    assert (np.asarray(idx) >= 0).all()
