"""L1 Pallas kernel: HDC random-projection encoder (paper Fig. 8a AFL stage).

h = step(F @ P.T) with P a fixed bipolar +-1 matrix. Tiled over hypervector
dimension blocks: each grid step matmuls the feature batch against one block
of projection rows on the MXU and thresholds on the VPU. The projection tile
streams HBM->VMEM; the feature batch stays resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(f_ref, p_ref, out_ref):
    z = jnp.dot(f_ref[...], p_ref[...].T)  # (B, block_d)
    out_ref[...] = (z > 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d",))
def hdc_encode(feats, proj, block_d=256):
    """Encode feats (B, n) with proj (D, n) of +-1 -> (B, D) f32 0/1."""
    b, n = feats.shape
    dims = proj.shape[0]
    block_d = min(block_d, dims)
    assert dims % block_d == 0, f"dims {dims} not divisible by block {block_d}"
    return pl.pallas_call(
        _encode_kernel,
        grid=(dims // block_d,),
        in_specs=[
            pl.BlockSpec((b, n), lambda i: (0, 0)),
            pl.BlockSpec((block_d, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, dims), jnp.float32),
        interpret=True,
    )(feats, proj)
