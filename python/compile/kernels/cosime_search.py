"""L1 Pallas kernel: in-memory squared-cosine NN search (the COSIME array).

Hardware adaptation (DESIGN.md §3): the analog crossbar's row-parallel dot
product maps to an MXU matmul over row *tiles* (BlockSpec grid = array
banks); the per-row translinear X^2/Y maps to a fused VPU elementwise on the
matmul result while it is still VMEM-resident; the WTA race maps to a
running (max, argmax) carried across the sequential row-tile grid in the
revisited output block — the digital analogue of the shared V_c rail.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical and the lowered HLO is what the Rust
runtime loads (see python/compile/aot.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _search_kernel(q_ref, cls_ref, y_ref, idx_ref, score_ref, *, block_rows):
    """One grid step: score a row tile, fold into the running argmax."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    # MXU: (B, D) x (D, block_rows) dot product = the crossbar's I_x currents.
    x = jnp.dot(q_ref[...], cls_ref[...].T)  # (B, block_rows)
    # VPU: translinear X^2 / Y (Eq. 6), fused in-register.
    y = jnp.maximum(y_ref[...], 1.0)[None, :]
    s = (x * x) / y

    # WTA: fold the tile winner into the running (max, argmax). Ties resolve
    # to the lowest row index (strict > across tiles, argmax within a tile).
    blk_best = jnp.max(s, axis=1)
    blk_arg = jnp.argmax(s, axis=1).astype(jnp.int32) + i * block_rows
    better = blk_best > score_ref[...]
    score_ref[...] = jnp.where(better, blk_best, score_ref[...])
    idx_ref[...] = jnp.where(better, blk_arg, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows",))
def cosime_search(q, cls, ycnt, block_rows=128):
    """NN search under squared cosine over row tiles.

    q: (B, D) f32 0/1; cls: (N, D) f32 0/1; ycnt: (N,) f32 popcounts.
    Returns (idx (B,) i32, score (B,) f32). N must be divisible by
    block_rows (pad with all-zero rows, which can never win: Y=0 -> s=0
    against initialized -inf ... all-zero rows score 0, still never beat any
    real row with s > 0; exact ties go to the lower index).
    """
    b, d = q.shape
    n = cls.shape[0]
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, f"rows {n} not divisible by block {block_rows}"
    grid = (n // block_rows,)
    kernel = functools.partial(_search_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),  # query tile: resident
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),  # row tile
            pl.BlockSpec((block_rows,), lambda i: (i,)),  # popcount tile
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),  # running argmax (revisited)
            pl.BlockSpec((b,), lambda i: (0,)),  # running max
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(q, cls, ycnt)


def _scores_kernel(q_ref, cls_ref, y_ref, out_ref):
    x = jnp.dot(q_ref[...], cls_ref[...].T)
    y = jnp.maximum(y_ref[...], 1.0)[None, :]
    out_ref[...] = (x * x) / y


@functools.partial(jax.jit, static_argnames=("block_rows",))
def cosime_scores(q, cls, ycnt, block_rows=128):
    """Full (B, N) score matrix (for waveform-level cross-checks)."""
    b, d = q.shape
    n = cls.shape[0]
    block_rows = min(block_rows, n)
    assert n % block_rows == 0
    return pl.pallas_call(
        _scores_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(q, cls, ycnt)
