"""L1 Pallas kernels (build-time only; lowered to HLO by compile/aot.py)."""

from .analog_mc import analog_mc_search
from .approx_cosine import approx_cosine_search
from .cosime_search import cosime_scores, cosime_search
from .hamming_search import hamming_search
from .hdc_encode import hdc_encode

__all__ = [
    "analog_mc_search",
    "approx_cosine_search",
    "cosime_scores",
    "cosime_search",
    "hamming_search",
    "hdc_encode",
]
