"""L1 Pallas kernel: Hamming-distance NN search (the CAM/TCAM baseline of
refs [6][9], used in the Fig. 1 / Fig. 9a metric comparisons).

Same tile structure as cosime_search; the per-tile score is the negated
Hamming distance computed from the dot product:
    d(a, b) = |a| + |b| - 2 a.b   for binary vectors.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, cls_ref, cb_ref, idx_ref, score_ref, *, block_rows):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    q = q_ref[...]
    x = jnp.dot(q, cls_ref[...].T)  # (B, block_rows)
    qa = jnp.sum(q, axis=1, keepdims=True)  # (B, 1)
    s = -(qa + cb_ref[...][None, :] - 2.0 * x)  # negated distance

    blk_best = jnp.max(s, axis=1)
    blk_arg = jnp.argmax(s, axis=1).astype(jnp.int32) + i * block_rows
    better = blk_best > score_ref[...]
    score_ref[...] = jnp.where(better, blk_best, score_ref[...])
    idx_ref[...] = jnp.where(better, blk_arg, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows",))
def hamming_search(q, cls, popcounts, block_rows=128):
    """NN by Hamming distance. Returns (idx (B,) i32, -distance (B,) f32).

    popcounts: (N,) f32 per-row |b| (precomputed, VMEM-resident alongside the
    tile exactly like the cosine kernel's Y vector).
    """
    b, d = q.shape
    n = cls.shape[0]
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, f"rows {n} not divisible by block {block_rows}"
    kernel = functools.partial(_hamming_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(q, cls, popcounts)
