"""L1 Pallas kernel: approximate-cosine NN search (the constant-denominator
scheme of ref [10], COSIME's headline comparator in Table 1).

The denominator ||b|| is frozen at a single constant, so the search is a
dot-product ranking scaled by 1/norm_const. The kernel keeps the scale so
returned scores are comparable with the reference implementation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _approx_kernel(q_ref, cls_ref, nc_ref, idx_ref, score_ref, *, block_rows):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = jnp.dot(q_ref[...], cls_ref[...].T)  # (B, block_rows)
    s = x / jnp.maximum(nc_ref[0], 1e-9)

    blk_best = jnp.max(s, axis=1)
    blk_arg = jnp.argmax(s, axis=1).astype(jnp.int32) + i * block_rows
    better = blk_best > score_ref[...]
    score_ref[...] = jnp.where(better, blk_best, score_ref[...])
    idx_ref[...] = jnp.where(better, blk_arg, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows",))
def approx_cosine_search(q, cls, norm_const, block_rows=128):
    """NN by approximate cosine. norm_const: (1,) f32 frozen denominator.

    Returns (idx (B,) i32, score (B,) f32).
    """
    b, d = q.shape
    n = cls.shape[0]
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, f"rows {n} not divisible by block {block_rows}"
    kernel = functools.partial(_approx_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(q, cls, norm_const)
