"""L1 Pallas kernel: variation-injected Monte Carlo search (paper Fig. 7).

Each trial is one fabricated die: a frozen per-row multiplicative gain error
(translinear loop + amplification mirror + WTA rail mismatch, lumped — see
rust/src/device/variation.rs for the per-component model this lumping is
calibrated against). The kernel scores every (trial, query) pair and returns
the per-trial winner, vectorizing the paper's 100-run Spectre MC.

Grid: (trials,). Per step the full score matrix for one die fits in VMEM
(B x N f32 <= 256 KiB at the paper's geometries).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mc_kernel(q_ref, cls_ref, y_ref, g_ref, win_ref):
    x = jnp.dot(q_ref[...], cls_ref[...].T)  # (B, N)
    y = jnp.maximum(y_ref[...], 1.0)[None, :]
    s = (x * x) / y * g_ref[0][None, :]  # die-specific gains
    win_ref[0, :] = jnp.argmax(s, axis=1).astype(jnp.int32)


@jax.jit
def analog_mc_search(q, cls, ycnt, gains):
    """Per-trial winners.

    q: (B, D); cls: (N, D); ycnt: (N,); gains: (T, N).
    Returns (T, B) i32 winner indices.
    """
    b, d = q.shape
    n = cls.shape[0]
    t = gains.shape[0]
    return pl.pallas_call(
        _mc_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, b), jnp.int32),
        interpret=True,
    )(q, cls, ycnt, gains)
