"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

These implement the paper's math directly:
  * squared-cosine scores  s = X**2 / Y   (paper Eq. 2, shared ||a||^2 dropped)
  * Hamming distance scores (refs [6][9] baseline)
  * approximate cosine (constant denominator, ref [10])
  * HDC random-projection encoding (paper Fig. 8a AFL stage)
  * variation-injected analog Monte Carlo scores (paper Fig. 7 model)

pytest (python/tests/) asserts the Pallas kernels match these to float
precision across hypothesis-swept shapes; the Rust digital engine mirrors the
same math on bit-packed words.
"""

import jax.numpy as jnp


def cosine_scores_ref(q, cls, ycnt):
    """Squared-cosine row scores.

    q: (B, D) float 0/1 queries; cls: (N, D) float 0/1 stored words;
    ycnt: (N,) float popcounts of cls. Returns (B, N) scores X^2/Y.
    """
    x = q @ cls.T  # (B, N) dot products
    y = jnp.maximum(ycnt, 1.0)[None, :]
    return (x * x) / y


def cosine_search_ref(q, cls, ycnt):
    """NN search under squared cosine: returns (idx (B,), score (B,))."""
    s = cosine_scores_ref(q, cls, ycnt)
    return jnp.argmax(s, axis=1).astype(jnp.int32), jnp.max(s, axis=1)


def hamming_scores_ref(q, cls):
    """Negated Hamming distances (higher = closer), (B, N)."""
    # d(a,b) = |a| + |b| - 2 a.b for binary vectors.
    x = q @ cls.T
    qa = jnp.sum(q, axis=1, keepdims=True)
    cb = jnp.sum(cls, axis=1)[None, :]
    return -(qa + cb - 2.0 * x)


def hamming_search_ref(q, cls):
    s = hamming_scores_ref(q, cls)
    return jnp.argmax(s, axis=1).astype(jnp.int32), jnp.max(s, axis=1)


def approx_cosine_scores_ref(q, cls, norm_const):
    """Constant-denominator approximate CSS (ref [10]): dot / norm_const."""
    return (q @ cls.T) / jnp.maximum(norm_const, 1e-9)


def approx_cosine_search_ref(q, cls, norm_const):
    s = approx_cosine_scores_ref(q, cls, norm_const)
    return jnp.argmax(s, axis=1).astype(jnp.int32), jnp.max(s, axis=1)


def hdc_encode_ref(feats, proj):
    """Random-projection binary encoding: step(feats @ proj.T).

    feats: (B, n) float features; proj: (D, n) float +-1 projection.
    Returns (B, D) float 0/1 hypervectors.
    """
    return (feats @ proj.T > 0.0).astype(jnp.float32)


def analog_mc_scores_ref(q, cls, ycnt, gains):
    """Variation-injected analog scores (Fig. 7 behavioral model).

    gains: (T, N) per-trial per-row multiplicative gain errors (frozen
    translinear + mirror + WTA-rail mismatch). Returns (T, B, N).
    """
    base = cosine_scores_ref(q, cls, ycnt)  # (B, N)
    return gains[:, None, :] * base[None, :, :]


def analog_mc_search_ref(q, cls, ycnt, gains):
    """Per-trial winners: (T, B) int32."""
    s = analog_mc_scores_ref(q, cls, ycnt, gains)
    return jnp.argmax(s, axis=2).astype(jnp.int32)


def exact_cosine_f32_ref(q, cls):
    """Full float cosine similarity (the GPU-baseline computation), (B, N)."""
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=1, keepdims=True), 1e-9)
    cn = cls / jnp.maximum(jnp.linalg.norm(cls, axis=1, keepdims=True), 1e-9)
    return qn @ cn.T
