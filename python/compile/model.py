"""L2 JAX model: the full compute graphs the Rust runtime executes.

Each public function here is a jit-able graph composed from the L1 Pallas
kernels (so the kernels lower into the same HLO module) plus glue math.
`compile/aot.py` lowers them at fixed shapes into artifacts/*.hlo.txt.

Entry points:
  * am_search_cosine   — the COSIME search (paper Eq. 2 + WTA)
  * am_search_hamming  — CAM/TCAM baseline search [6][9]
  * am_search_approx   — approximate-cosine baseline [10]
  * hdc_encode_batch   — random-projection encoder (AFL stage)
  * hdc_infer          — encoder + COSIME search fused into one module
  * analog_mc          — variation Monte Carlo (Fig. 7) over frozen gains
  * exact_cosine_f32   — full float cosine (the GPU comparator computation)
"""

import jax.numpy as jnp

from .kernels import (
    analog_mc_search,
    approx_cosine_search,
    cosime_search,
    hamming_search,
    hdc_encode,
)
from .kernels import ref


def _block_rows(n):
    """Largest power-of-two tile <= min(n, 128) that divides n."""
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= n and n % cand == 0:
            return cand
    return 1


def am_search_cosine(q, cls, ycnt):
    """COSIME search: (idx, score) per query (tuple output for jax.export)."""
    idx, score = cosime_search(q, cls, ycnt, block_rows=_block_rows(cls.shape[0]))
    return (idx, score)


def am_search_hamming(q, cls, popcounts):
    idx, score = hamming_search(q, cls, popcounts, block_rows=_block_rows(cls.shape[0]))
    return (idx, score)


def am_search_approx(q, cls, norm_const):
    idx, score = approx_cosine_search(
        q, cls, norm_const, block_rows=_block_rows(cls.shape[0])
    )
    return (idx, score)


def hdc_encode_batch(feats, proj):
    """Encode features to binary hypervectors (B, D) f32 0/1."""
    block = 256 if proj.shape[0] % 256 == 0 else _block_rows(proj.shape[0])
    return (hdc_encode(feats, proj, block_d=block),)


def hdc_infer(feats, proj, cls, ycnt):
    """End-to-end HDC inference: encode then COSIME-search, one HLO module.

    feats: (B, n); proj: (D, n) +-1; cls: (K, D); ycnt: (K,).
    Returns (class idx (B,) i32, score (B,) f32).
    """
    (h,) = hdc_encode_batch(feats, proj)
    return am_search_cosine(h, cls, ycnt)


def analog_mc(q, cls, ycnt, gains):
    """Per-trial winners under frozen per-die gains: (T, B) i32."""
    return (analog_mc_search(q, cls, ycnt, gains),)


def exact_cosine_f32(q, cls):
    """Full float cosine scores + argmax — the GPU-side computation the
    paper benchmarks against (Fig. 9b/c). Pure jnp (no Pallas): this is the
    *comparator*, not the contribution."""
    s = ref.exact_cosine_f32_ref(q, cls)
    return (jnp.argmax(s, axis=1).astype(jnp.int32), jnp.max(s, axis=1))
