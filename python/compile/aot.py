"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt       one per entry point x shape variant
  manifest.json        entry-point index the Rust runtime loads:
                       [{name, file, inputs: [{shape, dtype}], outputs: [...]}]

Run via `make artifacts` (no-op when inputs are unchanged).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """(name, fn, example-arg specs) for every artifact.

    Shape variants cover the serving tile (256 rows x 1024 bits, the paper's
    array), the Table-1 geometry, a small test geometry, and the HDC case
    study (ISOLET-like shapes padded to tile multiples).
    """
    eps = []

    def add(name, fn, args):
        eps.append((name, fn, args))

    for rows, dims, batch in [
        (256, 1024, 8),
        (256, 1024, 64),
        (256, 256, 8),
        (32, 128, 4),
    ]:
        add(
            f"cosime_search_r{rows}_d{dims}_b{batch}",
            model.am_search_cosine,
            [spec((batch, dims)), spec((rows, dims)), spec((rows,))],
        )
    add(
        "hamming_search_r256_d1024_b8",
        model.am_search_hamming,
        [spec((8, 1024)), spec((256, 1024)), spec((256,))],
    )
    add(
        "approx_search_r256_d1024_b8",
        model.am_search_approx,
        [spec((8, 1024)), spec((256, 1024)), spec((1,))],
    )
    # HDC end-to-end: ISOLET-like n=617 features, K=32 class rows (26 used,
    # padded to a tile multiple), D=1024.
    add(
        "hdc_encode_n617_d1024_b8",
        model.hdc_encode_batch,
        [spec((8, 617)), spec((1024, 617))],
    )
    add(
        "hdc_infer_n617_k32_d1024_b8",
        model.hdc_infer,
        [spec((8, 617)), spec((1024, 617)), spec((32, 1024)), spec((32,))],
    )
    add(
        "analog_mc_r64_d256_b4_t100",
        model.analog_mc,
        [spec((4, 256)), spec((64, 256)), spec((64,)), spec((100, 64))],
    )
    add(
        "exact_cosine_r256_d1024_b8",
        model.exact_cosine_f32,
        [spec((8, 1024)), spec((256, 1024))],
    )
    return eps


def lower_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, fn, args in entry_points():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_info = lowered.out_info
        flat_out, _ = jax.tree_util.tree_flatten(out_info)
        manifest.append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": str(o.dtype)} for o in flat_out
                ],
            }
        )
        print(f"lowered {name}: {len(text)} chars", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest)} artifacts to {out_dir}", file=sys.stderr)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=None, help="artifact output directory")
    p.add_argument("--out", default=None, help="(legacy) single-file target; directory is used")
    args = p.parse_args()
    out_dir = args.out_dir or (os.path.dirname(args.out) if args.out else "../artifacts")
    lower_all(out_dir)


if __name__ == "__main__":
    main()
