//! End-to-end driver (rust/DESIGN.md §E2E): the full HDC
//! classification pipeline of paper §4.2 on a real small workload, proving
//! all layers compose:
//!
//!   L2/L1 artifacts  — the hdc_infer HLO (Pallas encode + search kernels)
//!                      executed through the PJRT runtime,
//!   L3 coordinator   — class hypervectors served by the AM service with
//!                      dynamic batching,
//!   substrates       — analog engine cross-check + energy accounting.
//!
//! Workload: synthetic ISOLET (Table 2 shape, 26 classes, 617 features),
//! single-pass HDC training + 2 retrain epochs, D = 1024.
//!
//! Run: `make artifacts && cargo run --release --example hdc_classification`

use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::CosimeConfig;
use cosime::coordinator::{AmService, TileManager};
use cosime::energy::{EnergyModel, T_WTA_NOMINAL};
use cosime::hdc::{Dataset, DatasetSpec, HdcModel, SyntheticParams, TrainConfig};
use cosime::runtime::{RuntimeHandle, Tensor};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let sub = std::env::args()
        .nth(1)
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.10);
    let ds = Dataset::synthetic(
        DatasetSpec::Isolet,
        SyntheticParams { subsample: sub, ..Default::default() },
        42,
    );
    println!(
        "== HDC end-to-end: {} (synthetic, Table 2 shape) ==\n\
         {} train / {} test, {} classes, {} features, D = 1024",
        ds.name,
        ds.train_len(),
        ds.test_len(),
        ds.classes,
        ds.features
    );

    // ---- train (single-pass + retrain) ---------------------------------
    let t0 = Instant::now();
    let model = HdcModel::train(
        &ds,
        TrainConfig {
            dims: 1024,
            epochs: 2,
            seed: 9,
            encoder: cosime::hdc::EncoderKind::RandomProjection { threshold_scale: 0.0 },
        },
    );
    let class_hvs = model.class_hypervectors();
    println!("trained in {:.2} s", t0.elapsed().as_secs_f64());

    // ---- serve inference through the coordinator -----------------------
    let cfg = CosimeConfig::default();
    let tiles = TileManager::build(class_hvs.clone(), cfg.array.rows, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })?;
    let svc = AmService::start(&cfg.coordinator, tiles);
    let t1 = Instant::now();
    let mut correct = 0usize;
    for (x, &y) in ds.test_x.iter().zip(&ds.test_y) {
        let h = model.encoder.encode(x);
        let resp = svc.search_with_retry(h, 20).expect("serve");
        if resp.winner == y {
            correct += 1;
        }
    }
    let serve_wall = t1.elapsed();
    let acc = correct as f64 / ds.test_len() as f64;
    println!(
        "\ncoordinator inference: accuracy {:.1} % | {:.0} queries/s | metrics:\n{}",
        acc * 100.0,
        ds.test_len() as f64 / serve_wall.as_secs_f64(),
        svc.metrics().report()
    );
    svc.shutdown();

    // ---- the same inference through the AOT artifact (L1+L2 on PJRT) ---
    match RuntimeHandle::spawn("artifacts") {
        Ok(rt) => {
            let sig = rt.signature("hdc_infer_n617_k32_d1024_b8")?;
            let (batch, nfeat) = (sig.inputs[0].shape[0], sig.inputs[0].shape[1]);
            let krows = sig.inputs[2].shape[0];
            // Rebuild the projection exactly as the encoder holds it (±1).
            let mut proj = vec![0.0f32; 1024 * nfeat];
            let enc_rows = 1024;
            for i in 0..enc_rows {
                for j in 0..nfeat {
                    // encoder stores bit=1 ⇔ +1
                    proj[i * nfeat + j] = if probe_bit(&model, i, j) { 1.0 } else { -1.0 };
                }
            }
            let mut cls = vec![0.0f32; krows * 1024];
            let mut ycnt = vec![0.0f32; krows];
            for (k, hv) in class_hvs.iter().enumerate() {
                for (j, b) in hv.iter().enumerate() {
                    cls[k * 1024 + j] = f32::from(u8::from(b));
                }
                ycnt[k] = hv.count_ones() as f32;
            }
            let t2 = Instant::now();
            let mut xla_correct = 0usize;
            let mut tested = 0usize;
            for (chunk_x, chunk_y) in
                ds.test_x.chunks(batch).zip(ds.test_y.chunks(batch)).take(24)
            {
                let mut feats = vec![0.0f32; batch * nfeat];
                for (b, x) in chunk_x.iter().enumerate() {
                    feats[b * nfeat..(b + 1) * nfeat].copy_from_slice(x);
                }
                let out = rt.run(
                    "hdc_infer_n617_k32_d1024_b8",
                    vec![
                        Tensor::F32(feats, vec![batch, nfeat]),
                        Tensor::F32(proj.clone(), vec![1024, nfeat]),
                        Tensor::F32(cls.clone(), vec![krows, 1024]),
                        Tensor::F32(ycnt.clone(), vec![krows]),
                    ],
                )?;
                let idx = out[0].as_i32()?;
                for (b, &y) in chunk_y.iter().enumerate() {
                    tested += 1;
                    if idx[b] as usize == y {
                        xla_correct += 1;
                    }
                }
            }
            println!(
                "\nPJRT artifact inference (hdc_infer, Pallas encode+search fused): \
                 accuracy {:.1} % on {} queries | {:.1} µs/query",
                100.0 * xla_correct as f64 / tested.max(1) as f64,
                tested,
                t2.elapsed().as_secs_f64() * 1e6 / tested.max(1) as f64
            );
        }
        Err(e) => println!("\n(skipping PJRT path: {e})"),
    }

    // ---- headline metrics (paper Fig. 9 terms) --------------------------
    let em = EnergyModel::new(&cfg);
    let cost = em.nominal_search_cost(ds.classes.max(2), 1024, T_WTA_NOMINAL);
    println!(
        "\nmodeled COSIME search: {:.1} ns, {:.2} pJ per query ({} rails)",
        cost.latency * 1e9,
        cost.total() * 1e12,
        ds.classes
    );
    assert!(acc > 0.6, "end-to-end accuracy collapsed: {acc}");
    println!("\nhdc_classification end-to-end OK");
    Ok(())
}

/// Read one projection bit back from the trained model's encoder.
fn probe_bit(model: &HdcModel, row: usize, col: usize) -> bool {
    model.encoder.as_rp().expect("RP encoder").projection_bit(row, col)
}
