//! Load generator for the `cosimed` TCP frontend: N client threads drive a
//! server over real sockets and report throughput plus latency percentiles
//! — the benchmarkable host interface the serving story needs.
//!
//! Two phases per client thread:
//!   1. *latency probe* — strict request/response round trips, one batched
//!      search frame at a time, each wall-timed individually;
//!   2. *throughput* — pipelined windows (`depth` frames of `batch` queries
//!      back to back on one socket), wall-timed per window.
//!
//! Run against an external server:
//!   cargo run --release -- serve --listen 127.0.0.1:7411 --shards 2
//!   cargo run --release --example loadgen 127.0.0.1:7411
//! or self-hosted (no address / `self`): the example spins up an
//! in-process 2-shard server on an ephemeral port and drives that —
//! `self:eventloop` / `self:threaded` picks its I/O engine, so the two
//! can be compared on identical stores (the event-loop engine is built to
//! hold its throughput as the client count grows past what two OS threads
//! per connection can carry).
//!
//! Usage: loadgen [addr|self[:io]] [clients] [frames-per-client] [batch] [k] [depth]
//!
//! The single-client version of the same probe/pipeline shape is what
//! `cosime bench` records into the repo-root `BENCH_serving.json`
//! (p50/p99 µs + pipelined qps per I/O engine) — use this example when you
//! need multi-client scaling, the bench rail when you need a committed,
//! schema-validated number.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::{CosimeConfig, IoMode};
use cosime::server::{Client, CosimeServer, ErrorCode, ShardRouter, WireError};
use cosime::util::{percentile, rng, BitVec};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let addr_arg = args.next().unwrap_or_else(|| "self".to_string());
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);
    let frames: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(400);
    let batch: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);
    let k: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(3);
    let depth: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);

    // Self-host when no address was given: an in-process 2-shard server,
    // on either I/O engine (`self` = threaded, `self:eventloop` etc.).
    let (addr, server) = if addr_arg == "self" || addr_arg.starts_with("self:") {
        let mut cfg = CosimeConfig::default();
        cfg.server.listen = "127.0.0.1:0".to_string();
        cfg.server.shards = 2;
        cfg.server.io = match addr_arg.strip_prefix("self:") {
            Some(io) => IoMode::parse(io)?,
            None => IoMode::Threaded,
        };
        cfg.coordinator.workers = 2;
        let mut r = rng(11);
        let words: Vec<BitVec> =
            (0..2048).map(|_| BitVec::random(1024, 0.5, &mut r)).collect();
        let router = ShardRouter::build(&cfg, cfg.server.shards, cfg.array.rows, words, |w| {
            Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
        })?;
        let server = CosimeServer::serve(&cfg.server, router)?;
        println!(
            "self-hosted cosimed on {} (2 shards, {} io)",
            server.local_addr(),
            server.io_mode().as_str()
        );
        (server.local_addr().to_string(), Some(server))
    } else {
        (addr_arg, None)
    };

    // Discover the served store's shape.
    let mut probe = Client::connect_retry(addr.as_str(), 10, Duration::from_millis(50))?;
    let health = probe.health()?;
    println!(
        "server: {} rows x {} bits, {} shard(s), epoch {}",
        health.rows, health.dims, health.shards, health.epoch
    );
    let dims = health.dims as usize;
    drop(probe);

    let latencies_us = Mutex::new(Vec::<f64>::new()); // phase 1, per frame
    let windows_us = Mutex::new(Vec::<f64>::new()); // phase 2, per window
    let busy_retries = std::sync::atomic::AtomicUsize::new(0);
    let probe_frames = (frames / 4).max(1);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients as u64 {
            let addr = addr.as_str();
            let latencies_us = &latencies_us;
            let windows_us = &windows_us;
            let busy_retries = &busy_retries;
            s.spawn(move || {
                let mut r = rng(100 + c);
                let mut client = Client::connect_retry(addr, 10, Duration::from_millis(50))
                    .expect("connect");
                let queries = |r: &mut cosime::util::Rng, n: usize| -> Vec<BitVec> {
                    (0..n).map(|_| BitVec::random(dims, 0.5, r)).collect()
                };

                // Phase 1: strict round trips, exact per-frame latency.
                let mut mine = Vec::with_capacity(probe_frames);
                for _ in 0..probe_frames {
                    let qs = queries(&mut r, batch);
                    let t = Instant::now();
                    match client.search_batch(&qs, k) {
                        Ok(resp) => {
                            assert_eq!(resp.results.len(), batch);
                            mine.push(t.elapsed().as_secs_f64() * 1e6);
                        }
                        Err(e) if is_busy(&e) => {
                            busy_retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(e) => panic!("search failed: {e:#}"),
                    }
                }
                latencies_us.lock().unwrap().extend(mine);

                // Phase 2: pipelined windows for throughput.
                let mut mine = Vec::new();
                let mut done = 0usize;
                while done < frames {
                    let take = depth.min(frames - done);
                    let t = Instant::now();
                    let mut pipe = client.pipeline();
                    for _ in 0..take {
                        let qs = queries(&mut r, batch);
                        pipe.search_batch(&qs, k).expect("queue frame");
                    }
                    match pipe.finish() {
                        Ok(responses) => {
                            assert_eq!(responses.len(), take);
                            mine.push(t.elapsed().as_secs_f64() * 1e6);
                            done += take;
                        }
                        Err(e) if is_busy(&e) => {
                            // The connection is out of sync after a failed
                            // pipeline: reconnect and retry the window.
                            busy_retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            client = Client::connect_retry(addr, 10, Duration::from_millis(50))
                                .expect("reconnect");
                            std::thread::sleep(Duration::from_micros(500));
                        }
                        Err(e) => panic!("pipelined search failed: {e:#}"),
                    }
                }
                windows_us.lock().unwrap().extend(mine);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let lats = latencies_us.into_inner().unwrap();
    let wins = windows_us.into_inner().unwrap();
    let probe_queries = lats.len() * batch;
    let pipelined_queries = clients * frames * batch;
    println!(
        "\nlatency probe ({probe_queries} queries, {batch}/frame, k={k}):\n  \
         per-frame µs: p50={:.1} p90={:.1} p99={:.1} max={:.1}",
        percentile(&lats, 50.0),
        percentile(&lats, 90.0),
        percentile(&lats, 99.0),
        percentile(&lats, 100.0),
    );
    println!(
        "pipelined ({pipelined_queries} queries, depth {depth}):\n  \
         per-window µs: p50={:.1} p90={:.1} p99={:.1}",
        percentile(&wins, 50.0),
        percentile(&wins, 90.0),
        percentile(&wins, 99.0),
    );
    println!(
        "throughput: {:.0} queries/s over {:.2} s wall ({} clients, {} busy retries)",
        (probe_queries + pipelined_queries) as f64 / wall,
        wall,
        clients,
        busy_retries.load(std::sync::atomic::Ordering::Relaxed)
    );

    // Server-side view over the same wire.
    let mut probe = Client::connect(addr.as_str())?;
    let m = probe.metrics()?;
    println!(
        "server metrics: submitted={} completed={} busy={} mean_batch={:.1} \
         total µs p50={:.1} p99={:.1}",
        m.submitted,
        m.completed,
        m.rejected_busy,
        m.mean_batch_size,
        m.total_p50_us,
        m.total_p99_us
    );
    drop(probe);
    if let Some(server) = server {
        server.shutdown();
    }
    Ok(())
}

/// True when the error chain carries a server Busy (backpressure) frame.
fn is_busy(e: &anyhow::Error) -> bool {
    e.downcast_ref::<WireError>().is_some_and(|w| w.code == ErrorCode::Busy)
}
