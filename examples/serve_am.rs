//! Serving example: run the AM coordinator under a bursty synthetic load of
//! mixed top-k requests and report throughput, latency percentiles (overall
//! and per k), batching efficiency and backpressure behavior — the L3
//! serving story around the COSIME tiles.
//!
//! The store is built through the mutable-store path: every word is
//! programmed with the ±4 V write-verify loop ([`cosime::am::store`]),
//! snapshotted to disk and loaded back, so the server *warm-starts* from a
//! persisted AM. While clients search, a writer thread applies live
//! class-vector updates through the admin plane and verifies each one is
//! immediately servable — the write→serve loop closed under load.
//!
//! The client side drives the completion-based
//! [`Backend`](cosime::coordinator::Backend) surface (submit a batch,
//! wait on the [`Ticket`](cosime::coordinator::Ticket)) — the same trait
//! the TCP frontend serves from, here over a [`LocalBackend`] with zero
//! transport in between.
//!
//! Run: `cargo run --release --example serve_am [rows] [queries] [snapshot]`

use cosime::am::store::AmStore;
use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::CosimeConfig;
use cosime::coordinator::{AdminOp, AmService, Backend, LocalBackend, SubmitError, TileManager};
use cosime::util::{rng, BitVec};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let rows: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4096);
    let queries: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let snapshot_arg = args.next();
    let build_dims = 1024; // used only when a fresh snapshot has to be built

    let mut cfg = CosimeConfig::default();
    cfg.coordinator.workers = 4;
    cfg.coordinator.max_batch = 32;

    // ---- build + persist the store (write-verify accounted) ------------
    let snap_path = match snapshot_arg {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let dir = std::env::temp_dir().join(format!("cosime-serve-{}", std::process::id()));
            std::fs::create_dir_all(&dir)?;
            dir.join("am.json")
        }
    };
    if !snap_path.exists() {
        let mut r = rng(11);
        let mut store = AmStore::new(&cfg, build_dims);
        let t0 = Instant::now();
        for i in 0..rows {
            let w = BitVec::random(build_dims, 0.5, &mut r);
            store.insert(&format!("row-{i}"), &w)?;
        }
        store.save(&snap_path)?;
        println!(
            "programmed + snapshotted {} rows in {:.2} s ({})",
            store.rows(),
            t0.elapsed().as_secs_f64(),
            store.write_stats().report()
        );
    }

    // ---- warm start from disk ------------------------------------------
    let store = AmStore::load(&cfg, &snap_path)?;
    anyhow::ensure!(!store.is_empty(), "snapshot {snap_path:?} has no rows to serve");
    let rows = store.rows();
    let dims = store.dims(); // queries/updates follow the snapshot's geometry
    let tiles = TileManager::build(store.words().to_vec(), cfg.array.rows, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })?;
    println!(
        "warm start: {rows} words x {dims} b on {} tiles of {} rows | {} workers, batch<= {}, queue {}",
        tiles.tile_count(),
        cfg.array.rows,
        cfg.coordinator.workers,
        cfg.coordinator.max_batch,
        cfg.coordinator.queue_depth
    );
    let svc = AmService::start_with_config(&cfg, tiles);
    // The client side talks to the completion-based trait surface — the
    // exact shape the TCP frontend serves — over a local adapter.
    let backend = LocalBackend::new(svc.clone());

    let busy_retries = AtomicU64::new(0);
    let clients = 8u64;
    // Scenario-diverse load: most clients want the single winner, some want
    // ranked top-k readouts (recommendation / few-shot shapes).
    let ks: [usize; 8] = [1, 1, 1, 1, 1, 5, 10, 25];
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let backend = &backend;
            let busy_retries = &busy_retries;
            let k = ks[c as usize % ks.len()];
            s.spawn(move || {
                let mut r = rng(100 + c);
                for i in 0..queries as u64 / clients {
                    let q = BitVec::random(dims, 0.5, &mut r);
                    loop {
                        // Submit without blocking, then wait on the ticket
                        // (poll() would slot into an event loop instead).
                        match backend
                            .submit_search(std::slice::from_ref(&q), k)
                            .and_then(|ticket| ticket.wait())
                        {
                            Ok(batch) => {
                                assert_eq!(batch.results.len(), 1);
                                assert_eq!(batch.results[0].len(), k.min(rows), "ranked depth");
                                break;
                            }
                            Err(SubmitError::Busy) => {
                                busy_retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::yield_now();
                            }
                            Err(e) => panic!("{e}"),
                        }
                    }
                    // Bursty arrivals: brief stalls every 256 queries.
                    if i % 256 == 255 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                }
            });
        }
        // Live-update writer riding alongside the load: reprogram rows
        // through the admin plane and verify each is immediately servable.
        let svc2 = svc.clone();
        s.spawn(move || {
            let mut r = rng(777);
            for step in 0..16u64 {
                let row = (step as usize * 251) % rows;
                let word = BitVec::random(dims, 0.5, &mut r);
                let resp = svc2
                    .admin(AdminOp::Update { row, word: word.clone() })
                    .expect("live update");
                let report = resp.write.expect("update carries write cost");
                assert_eq!(report.failures, 0);
                // The clients keep the queue under backpressure by design,
                // so the verification search must ride the retry path.
                let hit =
                    svc2.search_topk_with_retry(word, 1, 50).expect("serve updated word");
                assert_eq!(hit.winner, row, "update visible to the next search");
                assert!(hit.epoch >= resp.epoch, "epoch ordering");
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
        });
    });
    let wall = t0.elapsed();
    let m = svc.metrics();
    println!("\n{}", m.report());
    println!(
        "\nthroughput: {:.0} queries/s ({} queries over {:.2} s, {} busy-retries)",
        m.completed as f64 / wall.as_secs_f64(),
        m.completed,
        wall.as_secs_f64(),
        busy_retries.load(Ordering::Relaxed)
    );
    println!(
        "live updates: epoch {} | write cost {} pulses, {:.2} nJ, {:.1} µs array time",
        svc.epoch(),
        m.write.pulses,
        m.write.energy_j * 1e9,
        m.write.latency_s * 1e6
    );
    drop(backend); // last service clone below joins the workers
    svc.shutdown();
    println!("serve_am OK");
    Ok(())
}
