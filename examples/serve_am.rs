//! Serving example: run the AM coordinator under a bursty synthetic load of
//! mixed top-k requests and report throughput, latency percentiles (overall
//! and per k), batching efficiency and backpressure behavior — the L3
//! serving story around the COSIME tiles.
//!
//! Run: `cargo run --release --example serve_am [rows] [queries]`

use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::CosimeConfig;
use cosime::coordinator::{AmService, SubmitError, TileManager};
use cosime::util::{rng, BitVec};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let rows: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4096);
    let queries: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let dims = 1024;

    let mut cfg = CosimeConfig::default();
    cfg.coordinator.workers = 4;
    cfg.coordinator.max_batch = 32;

    let mut r = rng(11);
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    let tiles = TileManager::build(words, cfg.array.rows, |w| {
        Ok(Box::new(DigitalExactEngine::new(w)) as Box<dyn AmEngine>)
    })?;
    println!(
        "serving {rows} words x {dims} b on {} tiles of {} rows | {} workers, batch<= {}, queue {}",
        tiles.tile_count(),
        cfg.array.rows,
        cfg.coordinator.workers,
        cfg.coordinator.max_batch,
        cfg.coordinator.queue_depth
    );
    let svc = AmService::start(&cfg.coordinator, tiles);

    let busy_retries = AtomicU64::new(0);
    let clients = 8u64;
    // Scenario-diverse load: most clients want the single winner, some want
    // ranked top-k readouts (recommendation / few-shot shapes).
    let ks: [usize; 8] = [1, 1, 1, 1, 1, 5, 10, 25];
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let svc = svc.clone();
            let busy_retries = &busy_retries;
            let k = ks[c as usize % ks.len()];
            s.spawn(move || {
                let mut r = rng(100 + c);
                for i in 0..queries as u64 / clients {
                    let q = BitVec::random(dims, 0.5, &mut r);
                    loop {
                        match svc.search_topk_blocking(q.clone(), k) {
                            Ok(resp) => {
                                assert_eq!(resp.hits.len(), k.min(rows), "ranked depth");
                                assert_eq!(resp.hits[0].winner, resp.winner);
                                break;
                            }
                            Err(SubmitError::Busy) => {
                                busy_retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::yield_now();
                            }
                            Err(e) => panic!("{e}"),
                        }
                    }
                    // Bursty arrivals: brief stalls every 256 queries.
                    if i % 256 == 255 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let m = svc.metrics();
    println!("\n{}", m.report());
    println!(
        "\nthroughput: {:.0} queries/s ({} queries over {:.2} s, {} busy-retries)",
        m.completed as f64 / wall.as_secs_f64(),
        m.completed,
        wall.as_secs_f64(),
        busy_retries.load(Ordering::Relaxed)
    );
    svc.shutdown();
    println!("serve_am OK");
    Ok(())
}
