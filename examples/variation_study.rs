//! Variation study (extends paper Fig. 7): sweep each variation source in
//! isolation and in combination to see which one limits COSIME's worst-case
//! search accuracy — an ablation the paper's Monte Carlo aggregates.
//!
//! Run: `cargo run --release --example variation_study [trials]`

use cosime::am::analog::AnalogCosimeEngine;
use cosime::am::AmEngine;
use cosime::config::{CosimeConfig, VariationConfig};
use cosime::repro::worst_case_pair;
use cosime::util::{child_seed, par, rng};

fn accuracy(cfg: &CosimeConfig, trials: usize, seed: u64) -> f64 {
    let (query, words, _) = worst_case_pair(32, 1024, seed);
    let hits: usize = par::par_map_idx(trials, |t| {
        let mut r = rng(child_seed(seed, t as u64));
        let engine = AnalogCosimeEngine::new(cfg, words.clone(), &mut r);
        usize::from(engine.search(&query).winner == 0)
    })
    .into_iter()
    .sum();
    hits as f64 / trials as f64
}

fn main() {
    let trials: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    println!("== variation ablation: worst-case pair (cos² = 1/4 vs 1/5), {trials} dies each ==");
    println!("{:<34} {:>10}", "variation sources enabled", "accuracy");

    let cases: Vec<(&str, VariationConfig)> = vec![
        ("none (nominal die)", VariationConfig {
            fefet_vth: false, resistor: false, mos: false, supply: false, sigma_supply_rel: 0.1,
        }),
        ("FeFET V_TH only", VariationConfig {
            fefet_vth: true, resistor: false, mos: false, supply: false, sigma_supply_rel: 0.1,
        }),
        ("1R resistor only (8 %)", VariationConfig {
            fefet_vth: false, resistor: true, mos: false, supply: false, sigma_supply_rel: 0.1,
        }),
        ("MOS mismatch only", VariationConfig {
            fefet_vth: false, resistor: false, mos: true, supply: false, sigma_supply_rel: 0.1,
        }),
        ("supply only (10 %)", VariationConfig {
            fefet_vth: false, resistor: false, mos: false, supply: true, sigma_supply_rel: 0.1,
        }),
        ("all (paper Fig. 7 setting)", VariationConfig::default()),
    ];

    let mut all_acc = 0.0;
    for (i, (name, var)) in cases.iter().enumerate() {
        let mut cfg = CosimeConfig::default();
        cfg.variation = var.clone();
        let acc = accuracy(&cfg, trials, 300 + i as u64);
        println!("{name:<34} {:>9.1}%", acc * 100.0);
        if name.starts_with("all") {
            all_acc = acc;
        }
    }
    println!(
        "\npaper Fig. 7a reports ≈90 % with all sources — measured {:.1} %",
        all_acc * 100.0
    );
    println!(
        "\nconclusion: the analog-stage (MOS) mismatch dominates; the 1FeFET1R\n\
         structure successfully suppresses the FeFET V_TH channel (paper §2.1)."
    );
    println!("variation_study OK");
}
