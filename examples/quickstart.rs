//! Quickstart: store binary words in a COSIME engine, run cosine-similarity
//! NN searches on all three backends (digital, analog circuit-sim, XLA
//! artifact), and print the energy/latency the analog model accounts.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use cosime::am::analog::AnalogCosimeEngine;
use cosime::am::{AmEngine, DigitalExactEngine};
use cosime::config::CosimeConfig;
use cosime::runtime::{RuntimeHandle, XlaAmEngine};
use cosime::util::{rng, BitVec};

fn main() -> anyhow::Result<()> {
    let cfg = CosimeConfig::default();
    let (rows, dims) = (32usize, 128usize);
    let mut r = rng(7);

    // 1. Store a set of binary words (e.g. class hypervectors).
    let words: Vec<BitVec> = (0..rows).map(|_| BitVec::random(dims, 0.5, &mut r)).collect();
    println!("stored {rows} words x {dims} bits");

    // 2. Build engines over the same contents.
    let digital = DigitalExactEngine::new(words.clone());
    let analog = AnalogCosimeEngine::nominal(&cfg, words.clone());
    let xla = RuntimeHandle::spawn("artifacts")
        .and_then(|rt| XlaAmEngine::new(&rt, "cosime_search_r32_d128_b4", &words));
    match &xla {
        Ok(_) => println!("engines: digital, analog, xla (artifact loaded)"),
        Err(e) => println!("engines: digital, analog (xla unavailable: {e})"),
    }

    // 3. Search: a noisy copy of word 12 must return row 12 under cosine.
    let mut query = words[12].clone();
    for _ in 0..6 {
        let j = r.below(dims);
        query.flip(j);
    }
    println!("\nquery = word 12 with 6 flipped bits");
    let d = digital.search(&query);
    println!("digital : winner={} score={:.3}", d.winner, d.score);
    let a = analog.search(&query);
    println!("analog  : winner={} score={:.3e} A", a.winner, a.score);
    if let Ok(x) = &xla {
        let xr = x.search(&query);
        println!("xla     : winner={} score={:.3}", xr.winner, xr.score);
    }
    assert_eq!(d.winner, 12);
    assert_eq!(a.winner, 12);

    // 4. Full analog search with transient WTA: latency + energy accounting.
    let out = analog.search_detailed(&query, false);
    println!(
        "\nanalog search cost: latency {:.2} ns | energy {:.2} pJ \
         (WTA {:.0} %, translinear {:.0} %)",
        out.cost.latency * 1e9,
        out.cost.total() * 1e12,
        out.cost.wta_fraction() * 100.0,
        out.cost.translinear_fraction() * 100.0
    );
    println!("quickstart OK");
    Ok(())
}
